//! detlint — determinism & panic-safety static analysis over the crate's
//! own sources.
//!
//! ConsumerBench's headline contract is byte-identical reports across
//! `--jobs 1/N`, repeats, resume, and queue backends. The golden-trace
//! tests enforce that *dynamically*, but only for hazards a seed happens
//! to exercise. This module makes the contract statically checkable: a
//! zero-dependency lint pass (hand-rolled lexer in [`lexer`], token-level
//! rules in [`rules`], cross-file pin checks in [`pins`]) that walks the
//! crate's own sources and reports every construct that could let host
//! state — hash seeds, wall clocks, OS entropy, poisoned locks, drifting
//! pinned literals — leak into report bytes.
//!
//! Scope model: files under `rust/src` get the full per-file rule set plus
//! pin scanning; `rust/tests` and `rust/benches` are pin-scan only (tests
//! and benches legitimately use wall clocks and literal seeds, but they
//! do assert pinned literals); `BENCH.json` and `python/perf_gate.py`
//! join the raw pin scan so schema markers and bench keys are compared
//! across language boundaries. `#[cfg(test)] mod` bodies inside `src` are
//! exempt from the per-file rules for the same reason. Fixture corpora
//! (any directory named `lint_fixtures`) are never walked.
//!
//! Suppressions are comment directives — the exact syntax, with examples,
//! is in the README ("Static analysis & the determinism contract"). A
//! directive must carry a non-empty `--` justification; a bare allow is
//! itself a diagnostic (`bad-suppression`) *and* leaves the underlying
//! violation live. Pin directives (`pin(key: value)`) assert cross-file
//! agreement of load-bearing literals and are validated against the file
//! text so an annotation cannot outlive the literal it protects.

mod lexer;
mod pins;
mod rules;

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use lexer::LineIndex;
use pins::{Pin, PinFile};

/// Every rule id with a one-line description (`consumerbench lint
/// --list-rules`).
pub const RULES: &[(&str, &str)] = &[
    (
        "no-unordered-iteration",
        "HashMap/HashSet in digest-affecting modules (gpusim, scenario, coordinator, server, apps)",
    ),
    (
        "no-wall-clock",
        "Instant::now/SystemTime anywhere outside the watchdog's documented boundary",
    ),
    (
        "no-poisonable-unwrap",
        ".lock().unwrap()/.lock().expect(...): double-panic on a poisoned mutex",
    ),
    (
        "no-float-order-hazard",
        ".sum::<f32|f64>() over hash-backed sources (float addition is order-sensitive)",
    ),
    (
        "no-ambient-entropy",
        "RNG construction outside util/rng.rs, or streams seeded from bare literals",
    ),
    (
        "pin-drift",
        "cross-file drift of pinned literals, schema markers, or BENCH.json keys",
    ),
    (
        "bad-suppression",
        "malformed, unknown-rule, or justification-free allow directives",
    ),
];

pub fn rule_exists(id: &str) -> bool {
    RULES.iter().any(|(r, _)| *r == id)
}

/// One lint finding, anchored to `file:line`.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub message: String,
}

impl Diagnostic {
    pub fn render(&self) -> String {
        format!("{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// The outcome of one lint run.
#[derive(Debug)]
pub struct LintReport {
    /// Surviving diagnostics, sorted by (file, line, rule).
    pub diagnostics: Vec<Diagnostic>,
    pub files_scanned: usize,
    /// Violations silenced by a justified allow directive.
    pub suppressions_honored: usize,
}

impl LintReport {
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }
}

/// What a file is scanned for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Scope {
    /// Per-file rules + directives + pin scan (`rust/src`).
    Code,
    /// Pin/marker scan only (`rust/tests`, `rust/benches`, artifacts).
    PinsOnly,
}

/// Find the repository root (the ancestor of `start` containing
/// `rust/src`).
pub fn find_root(start: &Path) -> Result<PathBuf> {
    for dir in start.ancestors() {
        if dir.join("rust").join("src").is_dir() {
            return Ok(dir.to_path_buf());
        }
    }
    bail!(
        "no repository root (a directory containing rust/src) at or above {}",
        start.display()
    )
}

/// Lint the repository rooted at `root`.
pub fn run_lint(root: &Path) -> Result<LintReport> {
    let files = collect_files(root)?;
    if files.is_empty() {
        bail!(
            "lint: no Rust sources found under {} (expected rust/src/**/*.rs)",
            root.display()
        );
    }
    let mut diagnostics = Vec::new();
    let mut suppressions_honored = 0usize;
    let mut pin_files = Vec::new();
    for (rel, scope) in &files {
        let raw = std::fs::read_to_string(root.join(rel))
            .with_context(|| format!("lint: read {rel}"))?;
        let mut pin_annotations = Vec::new();
        if rel.ends_with(".rs") {
            scan_rust_file(
                rel,
                &raw,
                *scope,
                &mut diagnostics,
                &mut suppressions_honored,
                &mut pin_annotations,
            );
        }
        pin_files.push(PinFile {
            rel: rel.clone(),
            raw,
            pins: pin_annotations,
        });
    }
    diagnostics.extend(pins::check(&pin_files));
    diagnostics.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    Ok(LintReport {
        diagnostics,
        files_scanned: files.len(),
        suppressions_honored,
    })
}

fn scan_rust_file(
    rel: &str,
    raw: &str,
    scope: Scope,
    diagnostics: &mut Vec<Diagnostic>,
    suppressions_honored: &mut usize,
    pin_annotations: &mut Vec<Pin>,
) {
    let masked = lexer::mask(raw);
    let (code, test_regions) = lexer::mask_cfg_test(&masked.code);
    let lines = LineIndex::new(raw);
    let mut allows: Vec<(String, usize)> = Vec::new();
    for c in &masked.comments {
        let Some(directive) = parse_directive(&c.text) else {
            continue;
        };
        match directive {
            Directive::Pin { key, value } => pin_annotations.push(Pin {
                line: c.line,
                key,
                value,
            }),
            Directive::Allow {
                rule,
                justification,
            } => {
                if scope != Scope::Code || in_regions(&test_regions, c.offset) {
                    continue;
                }
                if !rule_exists(&rule) {
                    diagnostics.push(Diagnostic {
                        rule: "bad-suppression",
                        file: rel.to_string(),
                        line: c.line,
                        message: format!(
                            "allow names unknown rule `{rule}` (see `consumerbench lint \
                             --list-rules`)"
                        ),
                    });
                } else if justification.is_empty() {
                    diagnostics.push(Diagnostic {
                        rule: "bad-suppression",
                        file: rel.to_string(),
                        line: c.line,
                        message: format!(
                            "allow for `{rule}` has no justification: a suppression \
                             must explain why the invariant holds (`-- <reason>`)"
                        ),
                    });
                } else {
                    allows.push((rule, c.line));
                }
            }
            Directive::Malformed(why) => diagnostics.push(Diagnostic {
                rule: "bad-suppression",
                file: rel.to_string(),
                line: c.line,
                message: format!("malformed detlint directive: {why}"),
            }),
        }
    }
    if scope == Scope::Code {
        let code_lines: Vec<&str> = code.lines().collect();
        for d in rules::run_rules(rel, &code, &lines) {
            let allowed = allows
                .iter()
                .any(|(rule, line)| *rule == d.rule && allow_covers(&code_lines, *line, d.line));
            if allowed {
                *suppressions_honored += 1;
            } else {
                diagnostics.push(d);
            }
        }
    }
}

/// Does an allow directive on `allow_line` cover a diagnostic on
/// `diag_line`? It does when they share a line (trailing comment) or when
/// every line between them is blank in the masked view — i.e. the
/// directive, possibly with justification continuation lines, immediately
/// precedes the flagged statement.
fn allow_covers(masked_lines: &[&str], allow_line: usize, diag_line: usize) -> bool {
    if diag_line == allow_line {
        return true;
    }
    if diag_line < allow_line {
        return false;
    }
    ((allow_line + 1)..diag_line)
        .all(|l| masked_lines.get(l - 1).is_none_or(|s| s.trim().is_empty()))
}

enum Directive {
    Allow { rule: String, justification: String },
    Pin { key: String, value: String },
    Malformed(String),
}

/// Parse a comment as a detlint directive. Only comments that *begin*
/// with the marker count — a mid-sentence mention in prose is not a
/// directive.
fn parse_directive(text: &str) -> Option<Directive> {
    let t = text
        .trim_start_matches(['/', '*', '!'])
        .trim_start()
        .trim_end_matches("*/")
        .trim_end();
    let rest = t.strip_prefix("detlint:")?.trim_start();
    if let Some(inner) = rest.strip_prefix("allow(") {
        let Some(close) = inner.find(')') else {
            return Some(Directive::Malformed("unclosed `allow(`".to_string()));
        };
        let rule = inner[..close].trim().to_string();
        if rule.is_empty() {
            return Some(Directive::Malformed("allow names no rule".to_string()));
        }
        let tail = inner[close + 1..].trim_start();
        let justification = tail
            .strip_prefix("--")
            .map(|j| j.trim().to_string())
            .unwrap_or_default();
        Some(Directive::Allow {
            rule,
            justification,
        })
    } else if let Some(inner) = rest.strip_prefix("pin(") {
        let Some(close) = inner.find(')') else {
            return Some(Directive::Malformed("unclosed `pin(`".to_string()));
        };
        let body = &inner[..close];
        let Some((k, v)) = body.split_once(':') else {
            return Some(Directive::Malformed(
                "pin takes `key: value`".to_string(),
            ));
        };
        let (key, value) = (k.trim(), v.trim());
        if key.is_empty() || value.is_empty() {
            return Some(Directive::Malformed(
                "pin takes `key: value`".to_string(),
            ));
        }
        Some(Directive::Pin {
            key: key.to_string(),
            value: value.to_string(),
        })
    } else {
        let head: String = rest.chars().take(24).collect();
        Some(Directive::Malformed(format!(
            "expected `allow(...)` or `pin(...)`, found `{head}`"
        )))
    }
}

fn in_regions(regions: &[(usize, usize)], offset: usize) -> bool {
    regions
        .iter()
        .any(|&(start, end)| offset >= start && offset <= end)
}

fn collect_files(root: &Path) -> Result<Vec<(String, Scope)>> {
    let mut files = Vec::new();
    walk_rs(&root.join("rust").join("src"), root, Scope::Code, &mut files)?;
    walk_rs(
        &root.join("rust").join("tests"),
        root,
        Scope::PinsOnly,
        &mut files,
    )?;
    walk_rs(
        &root.join("rust").join("benches"),
        root,
        Scope::PinsOnly,
        &mut files,
    )?;
    for artifact in ["BENCH.json", "python/perf_gate.py"] {
        if root.join(artifact).is_file() {
            files.push((artifact.to_string(), Scope::PinsOnly));
        }
    }
    files.sort();
    Ok(files)
}

fn walk_rs(
    dir: &Path,
    root: &Path,
    scope: Scope,
    out: &mut Vec<(String, Scope)>,
) -> Result<()> {
    if !dir.is_dir() {
        return Ok(());
    }
    let mut entries = Vec::new();
    for entry in
        std::fs::read_dir(dir).with_context(|| format!("lint: read dir {}", dir.display()))?
    {
        entries.push(entry?.path());
    }
    entries.sort();
    for path in entries {
        if path.is_dir() {
            let skip = path
                .file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n == "lint_fixtures" || n == "target");
            if !skip {
                walk_rs(&path, root, scope, out)?;
            }
        } else if path.extension().and_then(|x| x.to_str()) == Some("rs") {
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .to_string_lossy()
                .replace('\\', "/");
            out.push((rel, scope));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn allow_text(rule: &str, justification: &str) -> String {
        // Built by concatenation so this source file never contains a
        // literal directive-shaped comment of its own.
        let mut s = String::from("// detlint");
        s.push_str(": allow(");
        s.push_str(rule);
        s.push(')');
        if !justification.is_empty() {
            s.push_str(" -- ");
            s.push_str(justification);
        }
        s
    }

    #[test]
    fn directive_requires_leading_marker() {
        assert!(parse_directive("// prose mentioning detlint: allow(x) syntax").is_none());
        assert!(parse_directive("// nothing to see").is_none());
        let d = parse_directive(&allow_text("no-wall-clock", "watchdog boundary"));
        assert!(matches!(
            d,
            Some(Directive::Allow { rule, justification })
                if rule == "no-wall-clock" && justification == "watchdog boundary"
        ));
    }

    #[test]
    fn bare_allow_has_empty_justification() {
        let d = parse_directive(&allow_text("no-wall-clock", ""));
        assert!(
            matches!(d, Some(Directive::Allow { justification, .. }) if justification.is_empty())
        );
    }

    #[test]
    fn pin_directive_parses_key_value() {
        let mut s = String::from("// detlint");
        s.push_str(": pin(default-matrix-count: 68)");
        let d = parse_directive(&s);
        assert!(matches!(
            d,
            Some(Directive::Pin { key, value }) if key == "default-matrix-count" && value == "68"
        ));
    }

    #[test]
    fn unknown_directive_is_malformed() {
        let mut s = String::from("// detlint");
        s.push_str(": forbid(everything)");
        assert!(matches!(parse_directive(&s), Some(Directive::Malformed(_))));
    }

    #[test]
    fn block_comment_directive_sheds_closing_delimiter() {
        let mut s = String::from("/* detlint");
        s.push_str(": allow(no-wall-clock) -- boundary */");
        let d = parse_directive(&s);
        assert!(matches!(
            d,
            Some(Directive::Allow { justification, .. }) if justification == "boundary"
        ));
    }

    #[test]
    fn rules_registry_is_consistent() {
        assert_eq!(RULES.len(), 7);
        assert!(rule_exists("no-wall-clock"));
        assert!(rule_exists("pin-drift"));
        assert!(!rule_exists("no-such-rule"));
        // Ids stay unique.
        let mut ids: Vec<&str> = RULES.iter().map(|(r, _)| *r).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), RULES.len());
    }
}
