//! Cross-file pin consistency (`pin-drift`).
//!
//! Three independent checks, all over the **raw** file text (pins live in
//! comments, string literals, and non-Rust artifacts, so masking would hide
//! exactly what we need to see):
//!
//! 1. **Annotation pins** — every pin directive (see README for syntax)
//!    names a `key: value` pair. All annotations sharing a key must agree
//!    on the value, and each annotated file must actually contain the
//!    pinned value outside the directive lines themselves (so the
//!    annotation cannot outlive the literal it protects).
//! 2. **Schema markers** — the report/bench schema-version keys
//!    (`consumerbench_run`, `consumerbench_scenario_matrix`,
//!    `consumerbench_bench`) are emitted, asserted, and consumed in
//!    several files; the integer that follows each occurrence must agree
//!    tree-wide.
//! 3. **BENCH keys** — the entry names `microbench.rs` emits and the
//!    `"name"` keys in the committed `BENCH.json` must be the same set,
//!    or the perf gate silently compares nothing.

use std::collections::{BTreeMap, BTreeSet};

use super::lexer::{is_ident, LineIndex};
use super::rules::find_token;
use super::Diagnostic;

/// Report/bench schema-version markers pinned tree-wide. Formatted so no
/// digit trails a marker here (the scan needs a digit within a few bytes).
const MARKERS: &[&str] = &[
    "consumerbench_run",
    "consumerbench_scenario_matrix",
    "consumerbench_bench",
    "consumerbench_fleet",
];

/// How far past a marker occurrence the version integer may sit
/// (covers `": 2`, `\": 2,`, `") != 2`).
const MARKER_INT_WINDOW: usize = 8;

/// One pin annotation, already parsed out of a comment directive.
#[derive(Debug, Clone)]
pub struct Pin {
    pub line: usize,
    pub key: String,
    pub value: String,
}

/// One file as seen by the pin checks.
#[derive(Debug)]
pub struct PinFile {
    pub rel: String,
    pub raw: String,
    pub pins: Vec<Pin>,
}

pub fn check(files: &[PinFile]) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    annotation_pins(files, &mut diags);
    marker_versions(files, &mut diags);
    bench_keys(files, &mut diags);
    diags
}

fn annotation_pins(files: &[PinFile], diags: &mut Vec<Diagnostic>) {
    let mut groups: BTreeMap<&str, Vec<(&PinFile, &Pin)>> = BTreeMap::new();
    for f in files {
        for p in &f.pins {
            groups.entry(p.key.as_str()).or_default().push((f, p));
        }
    }
    for (key, sites) in &groups {
        let values: BTreeSet<&str> = sites.iter().map(|(_, p)| p.value.as_str()).collect();
        if values.len() > 1 {
            let seen = values.iter().copied().collect::<Vec<_>>().join("`, `");
            for (f, p) in sites {
                diags.push(Diagnostic {
                    rule: "pin-drift",
                    file: f.rel.clone(),
                    line: p.line,
                    message: format!(
                        "pin `{key}` drifted: this site pins `{}` but the tree pins \
                         `{seen}` — update every site in the same commit",
                        p.value
                    ),
                });
            }
        }
        for (f, p) in sites {
            if !anchored(&f.raw, &p.value) {
                diags.push(Diagnostic {
                    rule: "pin-drift",
                    file: f.rel.clone(),
                    line: p.line,
                    message: format!(
                        "pin `{key}` is unanchored: `{}` does not occur in this file \
                         outside the directive itself — the literal it pinned is gone",
                        p.value
                    ),
                });
            }
        }
    }
}

/// Does `value` occur in `raw`, boundary-aware, on a line that is not
/// itself a pin directive?
fn anchored(raw: &str, value: &str) -> bool {
    for line in raw.lines() {
        if line.contains("detlint:") {
            continue;
        }
        if !find_token(line, value).is_empty() {
            return true;
        }
    }
    false
}

fn marker_versions(files: &[PinFile], diags: &mut Vec<Diagnostic>) {
    for marker in MARKERS {
        let mut sites: Vec<(&PinFile, usize, u64)> = Vec::new();
        for f in files {
            let lines = LineIndex::new(&f.raw);
            for at in find_token(&f.raw, marker) {
                if let Some(v) = int_after(&f.raw, at + marker.len()) {
                    sites.push((f, lines.line_of(at), v));
                }
            }
        }
        let distinct: BTreeSet<u64> = sites.iter().map(|&(_, _, v)| v).collect();
        if distinct.len() > 1 {
            let seen = distinct
                .iter()
                .map(|v| v.to_string())
                .collect::<Vec<_>>()
                .join(", ");
            for (f, line, v) in sites {
                diags.push(Diagnostic {
                    rule: "pin-drift",
                    file: f.rel.clone(),
                    line,
                    message: format!(
                        "schema marker `{marker}` disagrees across the tree: this site \
                         says {v}, tree has {{{seen}}}"
                    ),
                });
            }
        }
    }
}

/// First integer within [`MARKER_INT_WINDOW`] bytes after `pos`, if any.
/// Sites with no nearby integer (docs, key lists) are not version claims.
fn int_after(raw: &str, pos: usize) -> Option<u64> {
    let bytes = raw.as_bytes();
    let mut j = pos;
    let stop = (pos + MARKER_INT_WINDOW).min(bytes.len());
    while j < stop && !bytes[j].is_ascii_digit() {
        j += 1;
    }
    if j >= stop {
        return None;
    }
    let start = j;
    while j < bytes.len() && bytes[j].is_ascii_digit() {
        j += 1;
    }
    raw[start..j].parse().ok()
}

fn bench_keys(files: &[PinFile], diags: &mut Vec<Diagnostic>) {
    let Some(mb) = files
        .iter()
        .find(|f| f.rel.ends_with("benches/microbench.rs"))
    else {
        return;
    };
    let Some(bj) = files.iter().find(|f| f.rel.ends_with("BENCH.json")) else {
        return;
    };
    let rust_keys = extract_keys(&mb.raw, "name: \"");
    let json_keys = extract_keys(&bj.raw, "\"name\": \"");
    for (key, line) in &rust_keys {
        if !json_keys.contains_key(key.as_str()) {
            diags.push(Diagnostic {
                rule: "pin-drift",
                file: mb.rel.clone(),
                line: *line,
                message: format!(
                    "bench entry `{key}` is emitted by microbench.rs but missing from \
                     the committed BENCH.json — the perf gate cannot see it"
                ),
            });
        }
    }
    for (key, line) in &json_keys {
        if !rust_keys.contains_key(key.as_str()) {
            diags.push(Diagnostic {
                rule: "pin-drift",
                file: bj.rel.clone(),
                line: *line,
                message: format!(
                    "bench entry `{key}` is in the committed BENCH.json but no longer \
                     emitted by microbench.rs — a stale baseline row"
                ),
            });
        }
    }
}

/// `pattern` immediately precedes each key; the key runs to the closing
/// quote. First-occurrence line is kept for the diagnostic.
fn extract_keys(raw: &str, pattern: &str) -> BTreeMap<String, usize> {
    let lines = LineIndex::new(raw);
    let bytes = raw.as_bytes();
    let mut out = BTreeMap::new();
    let mut from = 0;
    while let Some(rel) = raw[from..].find(pattern) {
        let at = from + rel;
        from = at + 1;
        if at > 0 && is_ident(bytes[at - 1]) {
            continue;
        }
        let start = at + pattern.len();
        let Some(len) = raw[start..].find('"') else {
            continue;
        };
        let key = raw[start..start + len].to_string();
        out.entry(key).or_insert_with(|| lines.line_of(at));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pf(rel: &str, raw: &str, pins: Vec<Pin>) -> PinFile {
        PinFile {
            rel: rel.to_string(),
            raw: raw.to_string(),
            pins,
        }
    }

    fn pin(line: usize, key: &str, value: &str) -> Pin {
        Pin {
            line,
            key: key.to_string(),
            value: value.to_string(),
        }
    }

    #[test]
    fn agreeing_anchored_pins_are_clean() {
        let a = pf("a.rs", "const N: usize = 68;\n", vec![pin(1, "count", "68")]);
        let b = pf("b.rs", "assert_eq!(n, 68);\n", vec![pin(1, "count", "68")]);
        assert!(check(&[a, b]).is_empty());
    }

    #[test]
    fn drifted_pins_flag_every_site() {
        let a = pf("a.rs", "const N: usize = 68;\n", vec![pin(1, "count", "68")]);
        let b = pf("b.rs", "assert_eq!(n, 70);\n", vec![pin(1, "count", "70")]);
        let diags = check(&[a, b]);
        assert_eq!(diags.len(), 2);
        assert!(diags.iter().all(|d| d.rule == "pin-drift"));
        assert!(diags[0].message.contains("drifted"));
    }

    #[test]
    fn unanchored_pin_is_flagged_and_boundary_aware() {
        // 168 must not anchor a pin of 68; the directive line itself must
        // not anchor it either.
        let a = pf(
            "a.rs",
            "const N: usize = 168; // detlint: not-an-anchor 68\n",
            vec![pin(1, "count", "68")],
        );
        let diags = check(&[a]);
        assert_eq!(diags.len(), 1);
        assert!(diags[0].message.contains("unanchored"));
    }

    #[test]
    fn marker_versions_must_agree() {
        // Assemble the marker name at runtime so this test file never
        // contains a drifting marker+integer pair in its own raw text.
        let emit = format!("out.push(\"\\\"consumerbench_{}\\\": 3\");\n", "run");
        let assert_line = format!("assert!(s.contains(\"consumerbench_{}\\\": 4\"));\n", "run");
        let a = pf("a.rs", &emit, vec![]);
        let b = pf("b.rs", &assert_line, vec![]);
        let diags = check(&[a, b]);
        assert_eq!(diags.len(), 2);
        assert!(diags[0].message.contains("disagrees"));
        // Agreeing versions: clean.
        let c = pf("c.rs", &emit, vec![]);
        let d = pf("d.rs", &emit, vec![]);
        assert!(check(&[c, d]).is_empty());
    }

    #[test]
    fn marker_without_nearby_integer_is_not_a_claim() {
        let doc = format!("// the consumerbench_{} marker is described here\n", "run");
        let emit = format!("out.push(\"\\\"consumerbench_{}\\\": 3\");\n", "run");
        assert!(check(&[pf("a.rs", &doc, vec![]), pf("b.rs", &emit, vec![])]).is_empty());
    }

    #[test]
    fn bench_key_sets_must_match() {
        let mb = pf(
            "rust/benches/microbench.rs",
            "Entry { name: \"alpha\" },\nEntry { name: \"beta\" },\n",
            vec![],
        );
        let bj = pf(
            "BENCH.json",
            "{\"name\": \"alpha\"}\n{\"name\": \"gamma\"}\n",
            vec![],
        );
        let diags = check(&[mb, bj]);
        assert_eq!(diags.len(), 2);
        assert!(diags.iter().any(|d| d.message.contains("`beta`")));
        assert!(diags.iter().any(|d| d.message.contains("`gamma`")));
        assert!(diags.iter().any(|d| d.file == "BENCH.json"));
    }
}
