//! Minimal Rust lexer for the determinism lint.
//!
//! The offline crate set has no `syn`/`proc-macro2`, and the lint does not
//! need a parse tree — every rule is a token-level pattern. What it *does*
//! need is to never confuse code with prose: a `HashMap` in a comment or a
//! string literal is documentation, not a hazard. So the lexer produces a
//! **masked** view of each source file: comments and literal bodies are
//! replaced by spaces (newlines preserved, so byte offsets and line numbers
//! stay aligned with the original), and the comments are captured on the
//! side for directive parsing.
//!
//! Handled literal forms: line comments, nested block comments, string
//! literals with escapes (including `\u{..}` and line continuations), char
//! literals (escaped and `'\''`), lifetimes (`'a`, `'static`, loop labels —
//! *not* blanked), raw strings `r"…"`/`r#"…"#` at any hash depth, byte
//! strings `b"…"`, byte chars `b'…'`, and raw byte strings `br#"…"#`. Raw
//! identifiers (`r#match`) fall through as plain code.

/// One comment, with the 1-based line and byte offset where it starts.
#[derive(Debug, Clone)]
pub struct Comment {
    pub line: usize,
    pub offset: usize,
    pub text: String,
}

/// The masked view of one source file.
#[derive(Debug)]
pub struct Masked {
    /// Source with comments and literal bodies blanked to spaces. Same byte
    /// length and line structure as the input.
    pub code: String,
    pub comments: Vec<Comment>,
}

/// Byte-offset → 1-based line number lookup.
#[derive(Debug)]
pub struct LineIndex {
    starts: Vec<usize>,
}

impl LineIndex {
    pub fn new(text: &str) -> LineIndex {
        let mut starts = vec![0];
        for (i, b) in text.bytes().enumerate() {
            if b == b'\n' {
                starts.push(i + 1);
            }
        }
        LineIndex { starts }
    }

    pub fn line_of(&self, offset: usize) -> usize {
        self.starts.partition_point(|&s| s <= offset)
    }
}

pub fn is_ident(b: u8) -> bool {
    b == b'_' || b.is_ascii_alphanumeric()
}

/// Blank comments and literal bodies out of `source`.
pub fn mask(source: &str) -> Masked {
    let mut lx = Lexer {
        src: source,
        bytes: source.as_bytes(),
        i: 0,
        line: 1,
        code: Vec::with_capacity(source.len()),
        comments: Vec::new(),
    };
    while lx.i < lx.bytes.len() {
        match lx.bytes[lx.i] {
            b'/' if lx.peek(1) == Some(b'/') => lx.line_comment(),
            b'/' if lx.peek(1) == Some(b'*') => lx.block_comment(),
            b'"' => lx.string_body(),
            b'\'' => lx.quote(),
            b'r' | b'b' if !lx.prev_is_ident() => lx.prefixed_literal(),
            _ => lx.keep(),
        }
    }
    Masked {
        code: String::from_utf8(lx.code).expect("blanking preserves UTF-8"),
        comments: lx.comments,
    }
}

/// Blank the bodies of `#[cfg(test)] mod … { … }` blocks in already-masked
/// code, returning the re-masked code and the blanked byte ranges. Test
/// modules exercise APIs under controlled conditions (literal seeds, panic
/// probes), so the determinism rules do not apply inside them.
pub fn mask_cfg_test(code: &str) -> (String, Vec<(usize, usize)>) {
    let mut out = code.as_bytes().to_vec();
    let mut regions = Vec::new();
    let mut from = 0;
    while let Some(rel) = code[from..].find("#[cfg(test)]") {
        let attr = from + rel;
        from = attr + 1;
        let mut j = attr + "#[cfg(test)]".len();
        // Skip whitespace and any further attributes before the item.
        loop {
            while out.get(j).copied().is_some_and(|b| b.is_ascii_whitespace()) {
                j += 1;
            }
            if out.get(j) == Some(&b'#') && out.get(j + 1) == Some(&b'[') {
                let mut depth = 0usize;
                while j < out.len() {
                    match out[j] {
                        b'[' => depth += 1,
                        b']' => {
                            depth -= 1;
                            if depth == 0 {
                                j += 1;
                                break;
                            }
                        }
                        _ => {}
                    }
                    j += 1;
                }
            } else {
                break;
            }
        }
        // Only `mod` items are masked; a `#[cfg(test)]` on anything else
        // (a lone helper fn, an import) is left to the rules.
        if !code[j..].starts_with("mod")
            || !out
                .get(j + 3)
                .copied()
                .is_some_and(|b| b.is_ascii_whitespace())
        {
            continue;
        }
        let Some(open_rel) = code[j..].find('{') else {
            continue;
        };
        let open = j + open_rel;
        let mut depth = 0usize;
        let mut k = open;
        let mut close = None;
        while k < out.len() {
            match out[k] {
                b'{' => depth += 1,
                b'}' => {
                    depth -= 1;
                    if depth == 0 {
                        close = Some(k);
                        break;
                    }
                }
                _ => {}
            }
            k += 1;
        }
        let Some(close) = close else {
            continue;
        };
        for b in &mut out[open + 1..close] {
            if *b != b'\n' {
                *b = b' ';
            }
        }
        regions.push((attr, close));
        from = close;
    }
    (
        String::from_utf8(out).expect("masking preserves UTF-8"),
        regions,
    )
}

struct Lexer<'a> {
    src: &'a str,
    bytes: &'a [u8],
    i: usize,
    line: usize,
    code: Vec<u8>,
    comments: Vec<Comment>,
}

impl Lexer<'_> {
    fn peek(&self, ahead: usize) -> Option<u8> {
        self.bytes.get(self.i + ahead).copied()
    }

    fn prev_is_ident(&self) -> bool {
        self.i > 0 && is_ident(self.bytes[self.i - 1])
    }

    /// Copy the current byte into the masked code verbatim.
    fn keep(&mut self) {
        let b = self.bytes[self.i];
        if b == b'\n' {
            self.line += 1;
        }
        self.code.push(b);
        self.i += 1;
    }

    /// Blank the current byte (newlines survive to keep lines aligned).
    fn blank(&mut self) {
        let b = self.bytes[self.i];
        if b == b'\n' {
            self.line += 1;
            self.code.push(b'\n');
        } else {
            self.code.push(b' ');
        }
        self.i += 1;
    }

    fn line_comment(&mut self) {
        let (start, line) = (self.i, self.line);
        while self.i < self.bytes.len() && self.bytes[self.i] != b'\n' {
            self.code.push(b' ');
            self.i += 1;
        }
        self.comments.push(Comment {
            line,
            offset: start,
            text: self.src[start..self.i].to_string(),
        });
    }

    fn block_comment(&mut self) {
        let (start, line) = (self.i, self.line);
        let mut depth = 0usize;
        while self.i < self.bytes.len() {
            if self.bytes[self.i] == b'/' && self.peek(1) == Some(b'*') {
                depth += 1;
                self.blank();
                self.blank();
            } else if self.bytes[self.i] == b'*' && self.peek(1) == Some(b'/') {
                depth -= 1;
                self.blank();
                self.blank();
                if depth == 0 {
                    break;
                }
            } else {
                self.blank();
            }
        }
        self.comments.push(Comment {
            line,
            offset: start,
            text: self.src[start..self.i].to_string(),
        });
    }

    /// At an opening `"`: blank the body, honoring escapes.
    fn string_body(&mut self) {
        self.code.push(b'"');
        self.i += 1;
        while self.i < self.bytes.len() {
            match self.bytes[self.i] {
                b'\\' => {
                    self.blank();
                    if self.i < self.bytes.len() {
                        self.blank();
                    }
                }
                b'"' => {
                    self.code.push(b'"');
                    self.i += 1;
                    return;
                }
                _ => self.blank(),
            }
        }
    }

    /// At an opening `'` of a char literal: blank the body.
    fn char_body(&mut self) {
        self.code.push(b'\'');
        self.i += 1;
        while self.i < self.bytes.len() {
            match self.bytes[self.i] {
                b'\\' => {
                    self.blank();
                    if self.i < self.bytes.len() {
                        self.blank();
                    }
                }
                b'\'' => {
                    self.code.push(b'\'');
                    self.i += 1;
                    return;
                }
                _ => self.blank(),
            }
        }
    }

    /// At a `'` that may open a char literal or a lifetime.
    fn quote(&mut self) {
        match self.peek(1) {
            Some(b'\\') => self.char_body(),
            Some(c) if c == b'_' || c.is_ascii_alphabetic() => {
                // `'a'` is a char; `'a`, `'static`, `'outer:` are lifetimes
                // or labels — left in the code view.
                let mut j = self.i + 2;
                while self.bytes.get(j).copied().is_some_and(is_ident) {
                    j += 1;
                }
                if self.bytes.get(j) == Some(&b'\'') {
                    self.char_body();
                } else {
                    self.keep();
                }
            }
            Some(_) => self.char_body(),
            None => self.keep(),
        }
    }

    /// At `r` or `b` on an identifier boundary: recognize raw/byte literal
    /// prefixes; anything else falls through as a plain identifier.
    fn prefixed_literal(&mut self) {
        if self.bytes[self.i] == b'r' {
            let mut j = self.i + 1;
            let mut hashes = 0usize;
            while self.bytes.get(j) == Some(&b'#') {
                hashes += 1;
                j += 1;
            }
            if self.bytes.get(j) == Some(&b'"') {
                self.keep(); // r
                for _ in 0..hashes {
                    self.keep();
                }
                self.raw_string_body(hashes);
            } else {
                self.keep();
            }
            return;
        }
        match self.peek(1) {
            Some(b'"') => {
                self.keep(); // b
                self.string_body();
            }
            Some(b'\'') => {
                self.keep(); // b
                self.char_body();
            }
            Some(b'r') => {
                let mut j = self.i + 2;
                let mut hashes = 0usize;
                while self.bytes.get(j) == Some(&b'#') {
                    hashes += 1;
                    j += 1;
                }
                if self.bytes.get(j) == Some(&b'"') {
                    self.keep(); // b
                    self.keep(); // r
                    for _ in 0..hashes {
                        self.keep();
                    }
                    self.raw_string_body(hashes);
                } else {
                    self.keep();
                }
            }
            _ => self.keep(),
        }
    }

    /// At the opening `"` of a raw string with `hashes` trailing `#`s.
    fn raw_string_body(&mut self, hashes: usize) {
        self.code.push(b'"');
        self.i += 1;
        while self.i < self.bytes.len() {
            if self.bytes[self.i] == b'"' && self.closing_hashes(hashes) {
                self.code.push(b'"');
                self.i += 1;
                for _ in 0..hashes {
                    self.keep(); // the delimiter #s
                }
                return;
            }
            self.blank();
        }
    }

    fn closing_hashes(&self, hashes: usize) -> bool {
        (1..=hashes).all(|k| self.bytes.get(self.i + k) == Some(&b'#'))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_and_strings_are_blanked() {
        let src = "let a = 1; // HashMap here\nlet s = \"Instant::now\"; /* SystemTime */\n";
        let m = mask(src);
        assert!(!m.code.contains("HashMap"));
        assert!(!m.code.contains("Instant"));
        assert!(!m.code.contains("SystemTime"));
        assert!(m.code.contains("let a = 1;"));
        assert_eq!(m.code.len(), src.len());
        assert_eq!(m.comments.len(), 2);
        assert_eq!(m.comments[0].line, 1);
        assert!(m.comments[0].text.contains("HashMap"));
        assert_eq!(m.comments[1].line, 2);
    }

    #[test]
    fn nested_block_comments_and_lines() {
        let src = "a /* x /* y */ z\nstill comment */ b\nc // tail";
        let m = mask(src);
        assert!(m.code.starts_with("a "));
        assert!(m.code.contains(" b\nc "));
        assert!(!m.code.contains("still"));
        assert_eq!(m.comments[0].line, 1);
        assert_eq!(mask("c // tail").comments[0].line, 1);
        // Line structure survives the multi-line comment.
        assert_eq!(m.code.matches('\n').count(), src.matches('\n').count());
    }

    #[test]
    fn raw_and_byte_literals() {
        let src = r####"let a = r#"HashMap "quoted""#; let b = br##"SystemTime"##; let c = b"lock()";"####;
        let m = mask(src);
        assert!(!m.code.contains("HashMap"));
        assert!(!m.code.contains("SystemTime"));
        assert!(!m.code.contains("lock"));
        assert!(m.code.contains("let a ="));
        assert!(m.code.contains("let c ="));
    }

    #[test]
    fn lifetimes_survive_char_literals_do_not() {
        let src = "fn f<'a>(x: &'a str) -> char { let c = 'x'; let q = '\\''; let n = '\\n'; c }";
        let m = mask(src);
        assert!(m.code.contains("<'a>"));
        assert!(m.code.contains("&'a str"));
        assert!(!m.code.contains('x'), "char body blanked: {}", m.code);
        assert_eq!(m.code.len(), src.len());
    }

    #[test]
    fn escaped_quotes_do_not_terminate() {
        let src = r#"let s = "a\"b"; tail()"#;
        let m = mask(src);
        assert!(m.code.contains("tail()"));
        assert!(!m.code.contains('b'));
    }

    #[test]
    fn cfg_test_modules_are_masked() {
        let code = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() { hazard() }\n}\nfn after() {}\n";
        let (masked, regions) = mask_cfg_test(code);
        assert!(!masked.contains("hazard"));
        assert!(masked.contains("fn live"));
        assert!(masked.contains("fn after"));
        assert_eq!(regions.len(), 1);
        // Nested braces inside the module stay balanced.
        let nested = "#[cfg(test)]\nmod t {\n    fn a() { if x { y() } }\n}\nkeep()\n";
        let (masked, _) = mask_cfg_test(nested);
        assert!(masked.contains("keep()"));
        assert!(!masked.contains("if x"));
    }

    #[test]
    fn line_index_maps_offsets() {
        let text = "ab\ncd\nef";
        let idx = LineIndex::new(text);
        assert_eq!(idx.line_of(0), 1);
        assert_eq!(idx.line_of(2), 1);
        assert_eq!(idx.line_of(3), 2);
        assert_eq!(idx.line_of(7), 3);
    }
}
