//! System monitor (§3.2, step ③, "system monitor").
//!
//! The paper samples DCGM (SMACT/SMOCC), pcm-memory (DRAM bandwidth), NVML
//! (GPU power), RAPL (CPU power), and `stat` (CPU utilization) at a fixed
//! wall-clock interval. Here the engine already records the ground-truth
//! piecewise-constant counter trace; this module resamples it onto the
//! monitor's fixed grid and derives the aggregate statistics the paper's
//! figures plot.

use crate::gpusim::trace::{Trace, TraceAggregates};
use crate::util::TimeSeries;

/// Monitor sampling interval (the paper samples at sub-second resolution).
pub const DEFAULT_INTERVAL: f64 = 0.1;

/// The resampled system-metric series for one scenario run.
#[derive(Debug, Clone)]
pub struct MonitorReport {
    pub gpu_smact: TimeSeries,
    pub gpu_smocc: TimeSeries,
    pub gpu_bw: TimeSeries,
    pub gpu_power: TimeSeries,
    pub vram_gib: TimeSeries,
    pub cpu_util: TimeSeries,
    pub dram_bw: TimeSeries,
    pub cpu_power: TimeSeries,
    /// Per-client (SMACT, SMOCC) series, indexed like the engine's clients.
    pub per_client: Vec<(TimeSeries, TimeSeries)>,
    pub interval: f64,
    /// Time-weighted means over the *raw* trace, restricted to intervals
    /// where the GPU was busy. Point-sampling a fixed grid aliases away
    /// sub-interval bursts (e.g. LiveCaptions' ~80 ms segments on a 2 s
    /// cadence); these integrals do not.
    busy_smact_tw: f64,
    busy_smocc_tw: f64,
}

impl MonitorReport {
    /// Resample an engine trace onto a fixed grid. The trace is piecewise
    /// constant: the value at grid time `t` is the last sample with
    /// `sample.t <= t`. Operates on the columnar [`Trace`] directly — the
    /// scalar sweep walks the dense row array and only the per-client loop
    /// touches the per-client column.
    ///
    /// `gpu_idle_w`/`cpu_idle_w` are the testbed's floor draws: grid points
    /// before the first trace sample are idle, not powered off, so they
    /// carry the idle watts (NVML/RAPL never read 0 W on a live board).
    pub fn from_trace(
        trace: &Trace,
        client_names: &[String],
        interval: f64,
        gpu_idle_w: f64,
        cpu_idle_w: f64,
    ) -> Self {
        assert!(interval > 0.0);
        let mut r = MonitorReport {
            gpu_smact: TimeSeries::new("SMACT", "frac"),
            gpu_smocc: TimeSeries::new("SMOCC", "frac"),
            gpu_bw: TimeSeries::new("GPU mem BW", "frac"),
            gpu_power: TimeSeries::new("GPU power", "W"),
            vram_gib: TimeSeries::new("VRAM", "GiB"),
            cpu_util: TimeSeries::new("CPU util", "frac"),
            dram_bw: TimeSeries::new("DRAM BW", "frac"),
            cpu_power: TimeSeries::new("CPU power", "W"),
            per_client: client_names
                .iter()
                .map(|n| {
                    (
                        TimeSeries::new(format!("{n} SMACT"), "frac"),
                        TimeSeries::new(format!("{n} SMOCC"), "frac"),
                    )
                })
                .collect(),
            interval,
            busy_smact_tw: 0.0,
            busy_smocc_tw: 0.0,
        };
        if trace.is_empty() {
            return r;
        }
        let rows = trace.rows();
        // Time-weighted busy means over the raw piecewise-constant trace.
        let mut busy_time = 0.0;
        let mut smact_int = 0.0;
        let mut smocc_int = 0.0;
        for w in rows.windows(2) {
            let dt = w[1].t - w[0].t;
            if w[0].gpu_smact > 1e-6 && dt > 0.0 {
                busy_time += dt;
                smact_int += w[0].gpu_smact as f64 * dt;
                smocc_int += w[0].gpu_smocc as f64 * dt;
            }
        }
        if busy_time > 0.0 {
            r.busy_smact_tw = smact_int / busy_time;
            r.busy_smocc_tw = smocc_int / busy_time;
        }
        let t_end = rows.last().unwrap().t;
        let mut idx = 0usize;
        let steps = (t_end / interval).ceil() as usize + 1;
        for k in 0..steps {
            // Clamp the final grid point to the end of the trace: when
            // `t_end` is not a multiple of `interval`, `ceil` would
            // otherwise place the last sample *past* the run, extending
            // every series and inflating the energy trapezoid integrals.
            let t = (k as f64 * interval).min(t_end);
            // Advance to the last sample at or before t.
            while idx + 1 < rows.len() && rows[idx + 1].t <= t {
                idx += 1;
            }
            let s = &rows[idx];
            if s.t > t {
                // Before the first sample: idle.
                r.push_idle(t, client_names.len(), gpu_idle_w, cpu_idle_w);
                continue;
            }
            r.gpu_smact.push(t, s.gpu_smact as f64);
            r.gpu_smocc.push(t, s.gpu_smocc as f64);
            r.gpu_bw.push(t, s.gpu_bw_frac as f64);
            r.gpu_power.push(t, s.gpu_power as f64);
            r.vram_gib.push(t, s.vram_used as f64 / (1u64 << 30) as f64);
            r.cpu_util.push(t, s.cpu_util as f64);
            r.dram_bw.push(t, s.dram_bw_frac as f64);
            r.cpu_power.push(t, s.cpu_power as f64);
            let pc = trace.per_client(idx);
            for (c, (act, occ)) in r.per_client.iter_mut().enumerate() {
                let (a, o) = pc.get(c).copied().unwrap_or((0.0, 0.0));
                act.push(t, a as f64);
                occ.push(t, o as f64);
            }
        }
        r
    }

    fn push_idle(&mut self, t: f64, n_clients: usize, gpu_idle_w: f64, cpu_idle_w: f64) {
        self.gpu_smact.push(t, 0.0);
        self.gpu_smocc.push(t, 0.0);
        self.gpu_bw.push(t, 0.0);
        // An idle device still draws its floor watts; recording 0 W here
        // deflated the energy trapezoid for runs with a pre-trace warmup.
        self.gpu_power.push(t, gpu_idle_w);
        self.vram_gib.push(t, 0.0);
        self.cpu_util.push(t, 0.0);
        self.dram_bw.push(t, 0.0);
        self.cpu_power.push(t, cpu_idle_w);
        for c in 0..n_clients {
            self.per_client[c].0.push(t, 0.0);
            self.per_client[c].1.push(t, 0.0);
        }
    }

    /// Time-weighted mean SMACT over GPU-busy intervals of the raw trace.
    pub fn mean_busy_smact(&self) -> f64 {
        self.busy_smact_tw
    }

    /// Time-weighted mean SMOCC over GPU-busy intervals of the raw trace.
    pub fn mean_busy_smocc(&self) -> f64 {
        self.busy_smocc_tw
    }

    /// GPU energy in joules (trapezoid over the power series).
    pub fn gpu_energy(&self) -> f64 {
        self.gpu_power.integral()
    }

    pub fn cpu_energy(&self) -> f64 {
        self.cpu_power.integral()
    }

    pub fn peak_vram_gib(&self) -> f64 {
        if self.vram_gib.is_empty() {
            0.0
        } else {
            self.vram_gib.max()
        }
    }
}

/// Scalar monitor summary computable in *both* trace modes.
///
/// [`MonitorReport`] needs the full materialized trace to resample onto its
/// grid; under `TraceMode::Streaming` only the tail window survives, so the
/// report cannot be rebuilt. This summary is derived from the engine's
/// [`TraceAggregates`] fold instead, which streams over every recorded row
/// in O(1) memory.
///
/// The busy means use the *same* fold, in the same order, as
/// [`MonitorReport::mean_busy_smact`] — they are bit-identical between the
/// two paths. The energies differ by construction: here they are exact
/// rectangle integrals over the raw piecewise-constant trace, whereas
/// [`MonitorReport::gpu_energy`] trapezoids over the resampled grid (and
/// includes the idle-floor warmup ramp). Prefer this summary for run-to-run
/// comparisons; prefer the report for plotting.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MonitorSummary {
    /// Recorded span in virtual seconds (`t_end - t_start`).
    pub span: f64,
    /// Total time with the GPU busy (`gpu_smact > 1e-6`).
    pub busy_time: f64,
    /// Time-weighted mean SMACT over busy time (0 if never busy).
    pub mean_busy_smact: f64,
    /// Time-weighted mean SMOCC over busy time (0 if never busy).
    pub mean_busy_smocc: f64,
    /// ∫ gpu_power dt over the raw trace span (joules, rectangle rule).
    pub gpu_energy_j: f64,
    /// ∫ cpu_power dt over the raw trace span (joules, rectangle rule).
    pub cpu_energy_j: f64,
    pub peak_vram_gib: f64,
    pub peak_gpu_power_w: f64,
    pub peak_cpu_power_w: f64,
}

impl MonitorSummary {
    /// Summarize a streamed fold — the only monitor view available when the
    /// engine ran with `TraceMode::Streaming`.
    pub fn from_aggregates(agg: &TraceAggregates) -> MonitorSummary {
        MonitorSummary {
            span: agg.span(),
            busy_time: agg.busy_time,
            mean_busy_smact: agg.mean_busy_smact(),
            mean_busy_smocc: agg.mean_busy_smocc(),
            gpu_energy_j: agg.gpu_energy_j,
            cpu_energy_j: agg.cpu_energy_j,
            peak_vram_gib: agg.peak_vram as f64 / (1u64 << 30) as f64,
            peak_gpu_power_w: agg.peak_gpu_power as f64,
            peak_cpu_power_w: agg.peak_cpu_power as f64,
        }
    }

    /// Summarize a fully materialized trace. Folds through
    /// [`TraceAggregates`] so full-mode and streaming-mode summaries of the
    /// same run are bit-identical.
    pub fn from_trace(trace: &Trace) -> MonitorSummary {
        MonitorSummary::from_aggregates(&TraceAggregates::from_trace(trace))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::trace::TraceSample;

    fn sample(t: f64, smact: f32, smocc: f32, clients: usize) -> TraceSample {
        TraceSample {
            t,
            gpu_smact: smact,
            gpu_smocc: smocc,
            gpu_bw_frac: 0.5,
            gpu_power: 150.0,
            vram_used: 2 << 30,
            cpu_util: 0.25,
            dram_bw_frac: 0.1,
            cpu_power: 50.0,
            per_client: vec![(smact, smocc); clients],
        }
    }

    #[test]
    fn resamples_piecewise_constant() {
        let trace = Trace::from_samples(&[
            sample(0.0, 1.0, 0.5, 1),
            sample(0.35, 0.5, 0.25, 1),
            sample(1.0, 0.0, 0.0, 1),
        ]);
        let names = vec!["app".to_string()];
        let r = MonitorReport::from_trace(&trace, &names, 0.1, 0.0, 0.0);
        // At t=0.0..0.3 → first sample; t=0.4..0.9 → second.
        assert_eq!(r.gpu_smact.values()[0], 1.0);
        assert_eq!(r.gpu_smact.values()[3], 1.0); // t=0.3 < 0.35
        assert_eq!(r.gpu_smact.values()[4], 0.5); // t=0.4 >= 0.35
        assert_eq!(*r.gpu_smact.values().last().unwrap(), 0.0);
        assert_eq!(r.per_client.len(), 1);
        assert_eq!(r.per_client[0].0.values()[0], 1.0);
    }

    #[test]
    fn empty_trace_is_empty_report() {
        let r = MonitorReport::from_trace(&Trace::new(), &[], 0.1, 0.0, 0.0);
        assert!(r.gpu_smact.is_empty());
        assert_eq!(r.gpu_energy(), 0.0);
    }

    #[test]
    fn busy_means_ignore_idle() {
        let trace = Trace::from_samples(&[
            sample(0.0, 0.0, 0.0, 0),
            sample(1.0, 0.8, 0.4, 0),
            sample(2.0, 0.0, 0.0, 0),
        ]);
        let r = MonitorReport::from_trace(&trace, &[], 0.5, 0.0, 0.0);
        // f32 storage in the trace → ~1e-8 rounding.
        assert!((r.mean_busy_smact() - 0.8).abs() < 1e-6);
        assert!((r.mean_busy_smocc() - 0.4).abs() < 1e-6);
    }

    #[test]
    fn energy_integrates_power() {
        let trace = Trace::from_samples(&[sample(0.0, 1.0, 0.5, 0), sample(10.0, 1.0, 0.5, 0)]);
        let r = MonitorReport::from_trace(&trace, &[], 1.0, 0.0, 0.0);
        // 150 W for 10 s = 1500 J.
        assert!((r.gpu_energy() - 1500.0).abs() < 1.0);
    }

    #[test]
    fn grid_clamps_to_unaligned_trace_end() {
        // Regression: a trace ending at 0.35 s on a 0.1 s grid used to get a
        // final sample at t = 0.4 s — past the run — inflating the energy
        // integral from 52.5 J (150 W × 0.35 s) to 60 J.
        let trace = Trace::from_samples(&[sample(0.0, 1.0, 0.5, 1), sample(0.35, 1.0, 0.5, 1)]);
        let names = vec!["app".to_string()];
        let r = MonitorReport::from_trace(&trace, &names, 0.1, 0.0, 0.0);
        let times = r.gpu_power.times();
        assert_eq!(
            *times.last().unwrap(),
            0.35,
            "last grid point must land on t_end, not past it"
        );
        assert!(
            times.windows(2).all(|w| w[1] > w[0]),
            "grid stays strictly increasing: {times:?}"
        );
        assert!((r.gpu_energy() - 150.0 * 0.35).abs() < 1e-9, "{}", r.gpu_energy());
        // Per-client series ride the same grid.
        assert_eq!(*r.per_client[0].0.times().last().unwrap(), 0.35);
        // Aligned traces are untouched (no duplicated end point).
        let aligned = Trace::from_samples(&[sample(0.0, 1.0, 0.5, 0), sample(0.4, 1.0, 0.5, 0)]);
        let ra = MonitorReport::from_trace(&aligned, &[], 0.1, 0.0, 0.0);
        assert_eq!(ra.gpu_power.times(), &[0.0, 0.1, 0.2, 0.3, 0.4]);
    }

    #[test]
    fn peak_vram() {
        let trace = Trace::from_samples(&[sample(0.0, 0.1, 0.1, 0)]);
        let r = MonitorReport::from_trace(&trace, &[], 0.1, 0.0, 0.0);
        assert!((r.peak_vram_gib() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn pre_trace_grid_points_carry_idle_power() {
        // Regression: a trace starting at 1.0 s on a 0.5 s grid used to
        // record 0 W at t = 0.0 and 0.5 — as if the board were unplugged —
        // undercounting energy by the idle draw of the warmup window.
        let trace = Trace::from_samples(&[sample(1.0, 1.0, 0.5, 1), sample(2.0, 1.0, 0.5, 1)]);
        let names = vec!["app".to_string()];
        let r = MonitorReport::from_trace(&trace, &names, 0.5, 55.0, 25.0);
        assert_eq!(r.gpu_power.values()[0], 55.0);
        assert_eq!(r.gpu_power.values()[1], 55.0);
        assert_eq!(r.gpu_power.values()[2], 150.0, "on-trace points unchanged");
        assert_eq!(r.cpu_power.values()[0], 25.0);
        // Activity series still read 0 before the run.
        assert_eq!(r.gpu_smact.values()[0], 0.0);
        assert_eq!(r.per_client[0].0.values()[0], 0.0);
        // Energy = idle ramp trapezoid + busy second. Pre-trace segment:
        // 55 W → 55 W over [0, 0.5] then 55 → 150 over [0.5, 1.0].
        let expect = 55.0 * 0.5 + (55.0 + 150.0) / 2.0 * 0.5 + 150.0;
        assert!((r.gpu_energy() - expect).abs() < 1e-9, "{}", r.gpu_energy());
        // With zero idle watts the old behaviour is preserved.
        let z = MonitorReport::from_trace(&trace, &names, 0.5, 0.0, 0.0);
        assert_eq!(z.gpu_power.values()[0], 0.0);
    }

    #[test]
    fn summary_busy_means_are_bit_identical_to_report() {
        // Irregular trace with idle gaps — the busy-mean fold in
        // MonitorSummary must reproduce MonitorReport's exactly (same ops,
        // same order), not just approximately.
        let trace = Trace::from_samples(&[
            sample(0.0, 0.0, 0.0, 0),
            sample(0.3, 0.8, 0.4, 0),
            sample(0.7, 0.3, 0.2, 0),
            sample(1.1, 0.0, 0.0, 0),
            sample(2.0, 0.9, 0.7, 0),
            sample(2.05, 0.0, 0.0, 0),
        ]);
        let r = MonitorReport::from_trace(&trace, &[], 0.1, 0.0, 0.0);
        let s = MonitorSummary::from_trace(&trace);
        assert_eq!(s.mean_busy_smact, r.mean_busy_smact());
        assert_eq!(s.mean_busy_smocc, r.mean_busy_smocc());
        assert!(s.busy_time > 0.0);
    }

    #[test]
    fn summary_energy_is_rectangle_over_raw_trace() {
        // Constant 150 W / 50 W over 10 s → 1500 J GPU, 500 J CPU exactly.
        let trace = Trace::from_samples(&[sample(0.0, 1.0, 0.5, 0), sample(10.0, 1.0, 0.5, 0)]);
        let s = MonitorSummary::from_trace(&trace);
        assert_eq!(s.span, 10.0);
        assert_eq!(s.gpu_energy_j, 150.0 * 10.0);
        assert_eq!(s.cpu_energy_j, 50.0 * 10.0);
        assert!((s.peak_vram_gib - 2.0).abs() < 1e-9);
        assert_eq!(s.peak_gpu_power_w, 150.0);
        assert_eq!(s.peak_cpu_power_w, 50.0);
        // And the aggregates path is the same struct, not a re-derivation.
        let agg = TraceAggregates::from_trace(&trace);
        assert_eq!(MonitorSummary::from_aggregates(&agg), s);
    }
}
