//! Command-line interface for the `consumerbench` binary.
//!
//! Hand-rolled argument parsing (the offline crate set has no `clap`):
//!
//! ```text
//! consumerbench run <config.yaml> [--artifacts DIR] [--csv FILE] [--json FILE] [--no-pjrt]
//! consumerbench validate <config.yaml>
//! consumerbench scenario [--seed N] [--jobs N] [--filter SUBSTR] [--backend KEY]
//!                        [--chaos KEY] [--queue KEY] [--trace-mode KEY]
//!                        [--trace-window N] [--out FILE] [--full] [--list]
//!                        [--dump DIR] [--fail-fast] [--journal FILE [--resume]]
//!                        [--watchdog-secs N] [--inject-panic SUBSTR]
//!                        [--inject-error SUBSTR]
//! consumerbench fleet [--devices N] [--seed N] [--population FILE] [--mix KEY]
//!                     [--strategy KEY] [--shard-size N] [--outliers K]
//!                     [--trace-window N] [--jobs N] [--out FILE]
//!                     [--journal FILE [--resume]] [--watchdog-secs N] [--list]
//! consumerbench lint [--root DIR] [--list-rules]
//! consumerbench apps
//! consumerbench help
//! ```

use anyhow::{bail, Context, Result};

use crate::analysis;
use crate::apps::{Application, Chatbot, DeepResearch, ImageGen, LiveCaptions};
use crate::coordinator::config::InjectFailure;
use crate::coordinator::{generate, to_csv, to_json_summary, BenchConfig, Dag, ScenarioRunner};
use crate::gpusim::backend::KernelBackend;
use crate::gpusim::chaos::ChaosKind;
use crate::gpusim::queue::QueueBackend;
use crate::gpusim::trace::{TraceMode, DEFAULT_STREAM_WINDOW};
use crate::runtime::Runtime;
use crate::coordinator::Strategy;
use crate::scenario::{
    backend_key, chaos_key, class_key, run_fleet, run_specs_supervised, AppMix, FleetOptions,
    FleetSpec, MatrixAxes, PopulationSpec, ScenarioSpec, SweepOptions,
};

const USAGE: &str = "\
ConsumerBench — benchmarking generative AI applications on end-user devices

USAGE:
    consumerbench run <config.yaml> [--artifacts DIR] [--csv FILE] [--json FILE] [--no-pjrt]
    consumerbench validate <config.yaml>
    consumerbench scenario [--seed N] [--jobs N] [--filter SUBSTR] [--backend KEY]
                           [--chaos KEY] [--queue KEY] [--trace-mode KEY]
                           [--trace-window N] [--out FILE] [--full] [--list]
                           [--dump DIR] [--fail-fast] [--journal FILE [--resume]]
                           [--watchdog-secs N] [--inject-panic SUBSTR]
                           [--inject-error SUBSTR]
    consumerbench fleet [--devices N] [--seed N] [--population FILE] [--mix KEY]
                        [--strategy KEY] [--shard-size N] [--outliers K]
                        [--trace-window N] [--jobs N] [--out FILE]
                        [--journal FILE [--resume]] [--watchdog-secs N] [--list]
    consumerbench lint [--root DIR] [--list-rules]
    consumerbench apps
    consumerbench help

COMMANDS:
    run        Execute a workflow configuration and print the benchmark report
    validate   Parse the configuration and check the workflow DAG
    scenario   Expand and execute the scenario matrix (app mix × policy ×
               testbed × arrival process × server mode × kernel backend ×
               chaos fault class, plus generated workflow DAG shapes with
               end-to-end latency and critical-path attribution), emitting
               an aggregate JSON report
    fleet      Sample a seeded synthetic device population (edge / laptop /
               desktop tiers) and sweep a scenario slice across it with
               bounded-memory streaming aggregation, emitting the
               population report (fleet-wide latency/attainment
               percentiles, per-tier breakdowns, worst-k outliers)
    lint       Statically analyze the crate's own sources for determinism
               and panic-safety hazards (hash-ordered iteration, wall
               clocks, poisonable lock unwraps, float-order hazards,
               ambient entropy, drifting pinned literals); exits nonzero
               on any diagnostic
    apps       List the built-in applications (paper Table 1)

OPTIONS (run):
    --artifacts DIR   AOT artifact directory (default: artifacts)
    --csv FILE        Also write per-request metrics as CSV
    --json FILE       Also write the machine-readable run summary as JSON
    --no-pjrt         Skip real-numerics PJRT execution even if artifacts exist

OPTIONS (scenario):
    --seed N          Matrix seed (default: 42); same seed => identical report
    --jobs N          Worker threads for the sweep (default: available
                      parallelism). The JSON report is byte-identical for
                      any N — scenarios are deterministic and independent
    --filter SUBSTR   Only expand scenarios whose name contains SUBSTR
                      (e.g. --filter server=adaptive, --filter mix=chat/,
                      --filter workflow=content_creation, --filter backend=)
    --backend KEY     Only expand scenarios running the given kernel backend
                      (tuned_native | generic_torch | fused_custom; every
                      scenario outside the ablation slice runs tuned_native)
    --chaos KEY       Only expand scenarios injecting the given fault class
                      (thermal_throttle | vram_ballast | suspend |
                      server_crash | pcie_degrade)
    --queue KEY       Event-queue backend for every selected scenario
                      (heap | wheel; default heap). Digest-neutral: the
                      JSON report is byte-identical under either backend
    --trace-mode KEY  Trace recording mode (full | streaming). Streaming
                      folds rows into the golden digest and windowed
                      aggregates with O(window) peak trace memory; digests
                      match full mode exactly
    --trace-window N  Materialized tail-row window for --trace-mode
                      streaming (default 512)
    --out FILE        Write the JSON report to FILE (default: print to stdout)
    --full            Sweep the full axes (periodic + trace arrivals, Apple
                      Silicon testbed, every policy on the workflow shapes
                      and the backend ablation) instead of the default 68
                      scenarios
    --list            Print scenario names without running anything
    --dump DIR        Write each expanded scenario config as YAML into DIR
    --fail-fast       Abort the sweep on the first non-ok scenario (legacy
                      semantics) instead of quarantining it and continuing;
                      no report is written on abort
    --journal FILE    Append every terminal outcome to FILE as a JSONL
                      checkpoint, keyed by (scenario name, seed, spec digest)
    --resume          Prefill completed scenarios from --journal and execute
                      only the rest; the report is byte-identical to an
                      uninterrupted run at any --jobs
    --watchdog-secs N Wall-clock watchdog per scenario attempt (defense in
                      depth only; timeout rows are host-dependent and never
                      journaled or digested)
    --inject-panic SUBSTR  Testing hook: panic at run start in scenarios
                      whose name contains SUBSTR
    --inject-error SUBSTR  Testing hook: fail at run start in scenarios
                      whose name contains SUBSTR

OPTIONS (fleet):
    --devices N       Population size (default: 200); overrides the file's
                      `count` when --population is also given
    --seed N          Population seed (default: 42); overrides the file's
                      `seed` when both are given
    --population FILE Load the population from a YAML spec (see README
                      \"Fleet sweeps\" for the schema) instead of the
                      default class weights
    --mix KEY         Application mix every device runs (chat |
                      chat_imagegen | captions_imagegen | full_stack;
                      default chat)
    --strategy KEY    Resource-sharing strategy (greedy | partition |
                      fair_share | slo_aware; default greedy)
    --shard-size N    Devices per aggregation shard (default 50). Changes
                      worker granularity and the float merge grouping, not
                      which devices run
    --outliers K      Worst-k attainment rows retained with their streaming
                      trace tails (default 8)
    --trace-window N  Streaming trace window per device (default 128)
    --jobs N          Worker threads (default: available parallelism); the
                      JSON report is byte-identical for any N
    --out FILE        Write the population report JSON to FILE
    --journal FILE    Append every terminal device record to FILE as a JSONL
                      checkpoint keyed by (device index, population seed,
                      fleet spec digest)
    --resume          Prefill completed devices from --journal and execute
                      only the rest; the report is byte-identical to an
                      uninterrupted run
    --watchdog-secs N Wall-clock watchdog per device attempt (timeout
                      records are host-dependent and never journaled)
    --list            Print the sampled device table without running anything

OPTIONS (lint):
    --root DIR        Repository root to lint (default: the nearest ancestor
                      of the current directory containing rust/src)
    --list-rules      Print the rule table and exit
";

/// Entry point used by `main.rs`.
pub fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    run_cli(&args, &mut std::io::stdout())
}

/// Testable CLI core.
pub fn run_cli(args: &[String], out: &mut impl std::io::Write) -> Result<()> {
    let Some(cmd) = args.first() else {
        writeln!(out, "{USAGE}")?;
        return Ok(());
    };
    match cmd.as_str() {
        "help" | "--help" | "-h" => {
            writeln!(out, "{USAGE}")?;
            Ok(())
        }
        "apps" => cmd_apps(out),
        "validate" => {
            let path = args.get(1).context("validate: missing <config.yaml>")?;
            cmd_validate(path, out)
        }
        "run" => {
            let path = args.get(1).context("run: missing <config.yaml>")?;
            let opts = parse_opts(&args[2..])?;
            cmd_run(path, &opts, out)
        }
        "scenario" => {
            let opts = parse_scenario_opts(&args[1..])?;
            cmd_scenario(&opts, out)
        }
        "fleet" => {
            let opts = parse_fleet_opts(&args[1..])?;
            cmd_fleet(&opts, out)
        }
        "lint" => {
            let opts = parse_lint_opts(&args[1..])?;
            cmd_lint(&opts, out)
        }
        other => bail!("unknown command `{other}`\n{USAGE}"),
    }
}

#[derive(Debug, Default)]
struct RunOpts {
    artifacts: Option<String>,
    csv: Option<String>,
    json: Option<String>,
    no_pjrt: bool,
}

fn parse_opts(args: &[String]) -> Result<RunOpts> {
    let mut opts = RunOpts {
        artifacts: Some("artifacts".to_string()),
        ..Default::default()
    };
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--artifacts" => {
                opts.artifacts = Some(
                    args.get(i + 1)
                        .context("--artifacts requires a value")?
                        .clone(),
                );
                i += 2;
            }
            "--csv" => {
                opts.csv = Some(args.get(i + 1).context("--csv requires a value")?.clone());
                i += 2;
            }
            "--json" => {
                opts.json = Some(args.get(i + 1).context("--json requires a value")?.clone());
                i += 2;
            }
            "--no-pjrt" => {
                opts.no_pjrt = true;
                i += 1;
            }
            other => bail!("unknown option `{other}`"),
        }
    }
    Ok(opts)
}

#[derive(Debug, Default)]
struct ScenarioOpts {
    seed: u64,
    /// Worker threads for the sweep; `None` = available parallelism.
    jobs: Option<usize>,
    /// Substring filter over scenario names (for iterating on a slice of
    /// the 68/276-scenario matrix).
    // detlint: pin(default-matrix-count: 68)
    // detlint: pin(full-matrix-count: 276)
    filter: Option<String>,
    /// Kernel-backend filter (`--backend KEY`); composes with `--filter`.
    backend: Option<KernelBackend>,
    /// Chaos fault-class filter (`--chaos KEY`); composes with the others.
    chaos: Option<ChaosKind>,
    /// Event-queue backend override applied to every selected scenario
    /// (`--queue heap|wheel`). Digest-neutral.
    queue: Option<QueueBackend>,
    /// Trace-mode override (`--trace-mode full|streaming`, optionally
    /// `--trace-window N`).
    trace_mode: Option<TraceMode>,
    out: Option<String>,
    full: bool,
    list: bool,
    dump: Option<String>,
    /// Abort on the first non-`ok` scenario instead of quarantining it.
    fail_fast: bool,
    /// JSONL checkpoint path (`--journal`).
    journal: Option<String>,
    /// Prefill completed scenarios from the journal (`--resume`).
    resume: bool,
    /// Wall-clock watchdog per scenario attempt, in seconds.
    watchdog_secs: Option<u64>,
    /// Testing hook: panic inside name-matching scenarios.
    inject_panic: Option<String>,
    /// Testing hook: fail name-matching scenarios.
    inject_error: Option<String>,
}

fn parse_scenario_opts(args: &[String]) -> Result<ScenarioOpts> {
    let mut opts = ScenarioOpts {
        seed: 42,
        ..Default::default()
    };
    // `--trace-mode`/`--trace-window` are order-independent, so collect
    // both raw and resolve after the loop.
    let mut trace_mode_key: Option<String> = None;
    let mut trace_window: Option<usize> = None;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--seed" => {
                opts.seed = args
                    .get(i + 1)
                    .context("--seed requires a value")?
                    .parse()
                    .context("--seed must be an integer")?;
                i += 2;
            }
            "--jobs" => {
                let jobs: usize = args
                    .get(i + 1)
                    .context("--jobs requires a value")?
                    .parse()
                    .context("--jobs must be an integer")?;
                if jobs == 0 {
                    bail!("--jobs must be >= 1");
                }
                opts.jobs = Some(jobs);
                i += 2;
            }
            "--filter" => {
                let f = args.get(i + 1).context("--filter requires a value")?;
                if f.is_empty() {
                    bail!("--filter must be a non-empty substring");
                }
                opts.filter = Some(f.clone());
                i += 2;
            }
            "--backend" => {
                let b = args.get(i + 1).context("--backend requires a value")?;
                opts.backend = Some(KernelBackend::parse(b).with_context(|| {
                    format!(
                        "--backend: unknown backend `{b}` (tuned_native | generic_torch | fused_custom)"
                    )
                })?);
                i += 2;
            }
            "--chaos" => {
                let c = args.get(i + 1).context("--chaos requires a value")?;
                opts.chaos = Some(ChaosKind::parse(c).with_context(|| {
                    format!(
                        "--chaos: unknown fault class `{c}` (thermal_throttle | vram_ballast | suspend | server_crash | pcie_degrade)"
                    )
                })?);
                i += 2;
            }
            "--queue" => {
                let q = args.get(i + 1).context("--queue requires a value")?;
                opts.queue = Some(
                    QueueBackend::parse(q)
                        .with_context(|| format!("--queue: unknown backend `{q}` (heap | wheel)"))?,
                );
                i += 2;
            }
            "--trace-mode" => {
                trace_mode_key = Some(
                    args.get(i + 1)
                        .context("--trace-mode requires a value")?
                        .clone(),
                );
                i += 2;
            }
            "--trace-window" => {
                let w: usize = args
                    .get(i + 1)
                    .context("--trace-window requires a value")?
                    .parse()
                    .context("--trace-window must be an integer")?;
                if w == 0 {
                    bail!("--trace-window must be >= 1");
                }
                trace_window = Some(w);
                i += 2;
            }
            "--out" => {
                opts.out = Some(args.get(i + 1).context("--out requires a value")?.clone());
                i += 2;
            }
            "--dump" => {
                opts.dump = Some(args.get(i + 1).context("--dump requires a value")?.clone());
                i += 2;
            }
            "--full" => {
                opts.full = true;
                i += 1;
            }
            "--list" => {
                opts.list = true;
                i += 1;
            }
            "--fail-fast" => {
                opts.fail_fast = true;
                i += 1;
            }
            "--journal" => {
                opts.journal = Some(
                    args.get(i + 1)
                        .context("--journal requires a value")?
                        .clone(),
                );
                i += 2;
            }
            "--resume" => {
                opts.resume = true;
                i += 1;
            }
            "--watchdog-secs" => {
                let secs: u64 = args
                    .get(i + 1)
                    .context("--watchdog-secs requires a value")?
                    .parse()
                    .context("--watchdog-secs must be an integer")?;
                if secs == 0 {
                    bail!("--watchdog-secs must be >= 1");
                }
                opts.watchdog_secs = Some(secs);
                i += 2;
            }
            "--inject-panic" => {
                opts.inject_panic = Some(
                    args.get(i + 1)
                        .context("--inject-panic requires a value")?
                        .clone(),
                );
                i += 2;
            }
            "--inject-error" => {
                opts.inject_error = Some(
                    args.get(i + 1)
                        .context("--inject-error requires a value")?
                        .clone(),
                );
                i += 2;
            }
            other => bail!("unknown option `{other}`"),
        }
    }
    if opts.resume && opts.journal.is_none() {
        bail!("--resume requires --journal");
    }
    opts.trace_mode = match trace_mode_key.as_deref() {
        None => {
            if let Some(w) = trace_window {
                bail!("--trace-window ({w}) requires --trace-mode streaming");
            }
            None
        }
        Some("full") => {
            if let Some(w) = trace_window {
                bail!("--trace-window ({w}) requires --trace-mode streaming");
            }
            Some(TraceMode::Full)
        }
        Some("streaming") => Some(TraceMode::Streaming {
            window: trace_window.unwrap_or(DEFAULT_STREAM_WINDOW),
        }),
        Some(other) => bail!("--trace-mode: unknown mode `{other}` (full | streaming)"),
    };
    Ok(opts)
}

fn cmd_scenario(opts: &ScenarioOpts, out: &mut impl std::io::Write) -> Result<()> {
    let axes = if opts.full {
        MatrixAxes::full_matrix(opts.seed)
    } else {
        MatrixAxes::default_matrix(opts.seed)
    };
    let mut specs: Vec<ScenarioSpec> = axes.expand();
    if let Some(filter) = &opts.filter {
        specs.retain(|s| s.name.contains(filter.as_str()));
        if specs.is_empty() {
            bail!("--filter `{filter}` matches no scenario (try `scenario --list`)");
        }
    }
    if let Some(backend) = opts.backend {
        specs.retain(|s| s.backend == backend);
        if specs.is_empty() {
            bail!(
                "--backend `{}` matches no scenario after filtering (try `scenario --list`)",
                backend_key(backend)
            );
        }
    }
    if let Some(kind) = opts.chaos {
        specs.retain(|s| s.chaos == Some(kind));
        if specs.is_empty() {
            bail!(
                "--chaos `{}` matches no scenario after filtering (try `scenario --list`)",
                chaos_key(kind)
            );
        }
    }
    // Execution knobs, not filters: applied to every selected scenario
    // (and therefore visible in `--dump` output).
    if let Some(queue) = opts.queue {
        for spec in specs.iter_mut() {
            spec.event_queue = Some(queue);
        }
    }
    if let Some(mode) = opts.trace_mode {
        for spec in specs.iter_mut() {
            spec.trace_mode = Some(mode);
        }
    }
    for (flag, substr, mode) in [
        ("--inject-panic", &opts.inject_panic, InjectFailure::Panic),
        ("--inject-error", &opts.inject_error, InjectFailure::Error),
    ] {
        if let Some(substr) = substr {
            let mut hits = 0;
            for spec in specs.iter_mut() {
                if spec.name.contains(substr.as_str()) {
                    spec.inject_failure = Some(mode);
                    hits += 1;
                }
            }
            if hits == 0 {
                bail!("{flag} `{substr}` matches no scenario (try `scenario --list`)");
            }
        }
    }
    if opts.list {
        for spec in &specs {
            writeln!(out, "{}", spec.name)?;
        }
        writeln!(out, "{} scenarios", specs.len())?;
        return Ok(());
    }
    if let Some(dir) = &opts.dump {
        std::fs::create_dir_all(dir).with_context(|| format!("creating {dir}"))?;
        for spec in &specs {
            let path = std::path::Path::new(dir).join(spec.file_name());
            std::fs::write(&path, spec.to_yaml())
                .with_context(|| format!("writing {}", path.display()))?;
        }
        writeln!(out, "wrote {} scenario configs to {dir}", specs.len())?;
        return Ok(());
    }
    let jobs = opts.jobs.unwrap_or_else(|| {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    });
    writeln!(
        out,
        "running {} scenarios (seed {}, jobs {}) …",
        specs.len(),
        opts.seed,
        jobs
    )?;
    let sweep = SweepOptions {
        jobs,
        fail_fast: opts.fail_fast,
        watchdog: opts.watchdog_secs.map(std::time::Duration::from_secs),
        journal: opts.journal.as_ref().map(std::path::PathBuf::from),
        resume: opts.resume,
    };
    let report = run_specs_supervised(&specs, opts.seed, &sweep)?;
    let quarantined = report
        .scenarios
        .iter()
        .filter(|s| !s.status.is_ok())
        .count();
    if opts.fail_fast && quarantined > 0 {
        // Legacy abort semantics: surface the lowest-index failure and
        // write no report.
        let first = report
            .scenarios
            .iter()
            .find(|s| !s.status.is_ok())
            .expect("counted a non-ok row");
        bail!(
            "scenario `{}` {}: {}",
            first.name,
            first.status.key(),
            first.error.as_deref().unwrap_or("aborted")
        );
    }
    write!(out, "{}", report.summary_table())?;
    writeln!(
        out,
        "policies covered: {}",
        report.strategies().join(", ")
    )?;
    let json = report.to_json();
    match &opts.out {
        Some(path) => {
            std::fs::write(path, &json).with_context(|| format!("writing {path}"))?;
            writeln!(out, "wrote JSON report to {path}")?;
        }
        None => write!(out, "{json}")?,
    }
    if quarantined > 0 {
        // The report is complete and written; the sweep itself still did
        // not fully succeed, so exit nonzero.
        bail!(
            "{quarantined} of {} scenarios did not complete (see summary.failures)",
            report.scenarios.len()
        );
    }
    Ok(())
}

#[derive(Debug, Default)]
struct FleetCliOpts {
    /// Population size (`--devices`); `None` = file's `count` or 200.
    devices: Option<usize>,
    /// Population seed (`--seed`); `None` = file's `seed` or 42.
    seed: Option<u64>,
    /// Population YAML spec path (`--population`).
    population: Option<String>,
    /// Application-mix key (`--mix`).
    mix: Option<String>,
    /// Strategy key (`--strategy`).
    strategy: Option<Strategy>,
    shard_size: Option<usize>,
    outlier_k: Option<usize>,
    trace_window: Option<usize>,
    /// Worker threads; `None` = available parallelism.
    jobs: Option<usize>,
    out: Option<String>,
    journal: Option<String>,
    resume: bool,
    watchdog_secs: Option<u64>,
    list: bool,
}

fn parse_fleet_opts(args: &[String]) -> Result<FleetCliOpts> {
    let mut opts = FleetCliOpts::default();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--devices" => {
                opts.devices = Some(
                    args.get(i + 1)
                        .context("--devices requires a value")?
                        .parse()
                        .context("--devices must be an integer")?,
                );
                i += 2;
            }
            "--seed" => {
                opts.seed = Some(
                    args.get(i + 1)
                        .context("--seed requires a value")?
                        .parse()
                        .context("--seed must be an integer")?,
                );
                i += 2;
            }
            "--population" => {
                opts.population = Some(
                    args.get(i + 1)
                        .context("--population requires a value")?
                        .clone(),
                );
                i += 2;
            }
            "--mix" => {
                opts.mix = Some(args.get(i + 1).context("--mix requires a value")?.clone());
                i += 2;
            }
            "--strategy" => {
                let key = args.get(i + 1).context("--strategy requires a value")?;
                opts.strategy = Some(Strategy::parse(key).with_context(|| {
                    format!(
                        "--strategy: unknown strategy `{key}` (greedy | partition | \
                         fair_share | slo_aware)"
                    )
                })?);
                i += 2;
            }
            "--shard-size" => {
                let n: usize = args
                    .get(i + 1)
                    .context("--shard-size requires a value")?
                    .parse()
                    .context("--shard-size must be an integer")?;
                if n == 0 {
                    bail!("--shard-size must be at least 1");
                }
                opts.shard_size = Some(n);
                i += 2;
            }
            "--outliers" => {
                opts.outlier_k = Some(
                    args.get(i + 1)
                        .context("--outliers requires a value")?
                        .parse()
                        .context("--outliers must be an integer")?,
                );
                i += 2;
            }
            "--trace-window" => {
                let n: usize = args
                    .get(i + 1)
                    .context("--trace-window requires a value")?
                    .parse()
                    .context("--trace-window must be an integer")?;
                if n == 0 {
                    bail!("--trace-window must be at least 1");
                }
                opts.trace_window = Some(n);
                i += 2;
            }
            "--jobs" => {
                opts.jobs = Some(
                    args.get(i + 1)
                        .context("--jobs requires a value")?
                        .parse()
                        .context("--jobs must be an integer")?,
                );
                i += 2;
            }
            "--out" => {
                opts.out = Some(args.get(i + 1).context("--out requires a value")?.clone());
                i += 2;
            }
            "--journal" => {
                opts.journal = Some(
                    args.get(i + 1)
                        .context("--journal requires a value")?
                        .clone(),
                );
                i += 2;
            }
            "--resume" => {
                opts.resume = true;
                i += 1;
            }
            "--watchdog-secs" => {
                opts.watchdog_secs = Some(
                    args.get(i + 1)
                        .context("--watchdog-secs requires a value")?
                        .parse()
                        .context("--watchdog-secs must be an integer")?,
                );
                i += 2;
            }
            "--list" => {
                opts.list = true;
                i += 1;
            }
            other => bail!("unknown option `{other}`"),
        }
    }
    if opts.resume && opts.journal.is_none() {
        bail!("--resume requires --journal");
    }
    Ok(opts)
}

/// Resolve a `--mix` key to its generator (the matrix's curated mixes).
fn mix_for_key(key: &str) -> Result<AppMix> {
    Ok(match key {
        "chat" => AppMix::chat(),
        "chat_imagegen" => AppMix::chat_imagegen(),
        "captions_imagegen" => AppMix::captions_imagegen(),
        "full_stack" => AppMix::full_stack(),
        other => bail!(
            "--mix: unknown mix `{other}` (chat | chat_imagegen | \
             captions_imagegen | full_stack)"
        ),
    })
}

fn cmd_fleet(opts: &FleetCliOpts, out: &mut impl std::io::Write) -> Result<()> {
    let mut population = match &opts.population {
        Some(path) => {
            let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
            PopulationSpec::parse_yaml(&text).with_context(|| format!("parsing {path}"))?
        }
        None => PopulationSpec::default_population(opts.devices.unwrap_or(200), 42),
    };
    // Explicit flags override the file (or the defaults).
    if let Some(n) = opts.devices {
        population.count = n;
    }
    if let Some(s) = opts.seed {
        population.seed = s;
    }
    if population.count == 0 {
        bail!("--devices must be at least 1");
    }
    let mut spec = FleetSpec::new(population);
    if let Some(key) = &opts.mix {
        spec.mix = mix_for_key(key)?;
    }
    if let Some(strategy) = opts.strategy {
        spec.strategy = strategy;
    }
    if let Some(n) = opts.shard_size {
        spec.shard_size = n;
    }
    if let Some(k) = opts.outlier_k {
        spec.outlier_k = k;
    }
    if let Some(w) = opts.trace_window {
        spec.trace_window = w;
    }
    if opts.list {
        for i in 0..spec.population.count {
            let dev = spec.population.device(i);
            writeln!(
                out,
                "device-{i:05}  {:7} {:>3} GB  ({}, {} SMs, {:.0} GB/s)",
                class_key(dev.class),
                dev.vram_gb,
                dev.testbed.gpu.name,
                dev.testbed.gpu.num_sms,
                dev.testbed.gpu.mem_bw / 1e9,
            )?;
        }
        writeln!(out, "{} devices ({} shards)", spec.population.count, spec.shards())?;
        return Ok(());
    }
    let jobs = opts.jobs.unwrap_or_else(|| {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    });
    writeln!(
        out,
        "sweeping {} devices in {} shards (seed {}, jobs {}) …",
        spec.population.count,
        spec.shards(),
        spec.population.seed,
        jobs
    )?;
    let fleet_opts = FleetOptions {
        jobs,
        watchdog: opts.watchdog_secs.map(std::time::Duration::from_secs),
        journal: opts.journal.as_ref().map(std::path::PathBuf::from),
        resume: opts.resume,
    };
    let report = run_fleet(&spec, &fleet_opts)?;
    write!(out, "{}", report.summary_table())?;
    let json = report.to_json();
    match &opts.out {
        Some(path) => {
            std::fs::write(path, &json).with_context(|| format!("writing {path}"))?;
            writeln!(out, "wrote population report to {path}")?;
        }
        None => write!(out, "{json}")?,
    }
    // Device failures are population phenomena recorded in the report
    // (`devices.failed` etc.), not sweep errors — unlike `scenario`, the
    // fleet command exits zero as long as the sweep infrastructure held.
    Ok(())
}

#[derive(Debug, Default)]
struct LintOpts {
    /// Repository root; `None` = walk up from the current directory.
    root: Option<String>,
    list_rules: bool,
}

fn parse_lint_opts(args: &[String]) -> Result<LintOpts> {
    let mut opts = LintOpts::default();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--root" => {
                opts.root = Some(args.get(i + 1).context("--root requires a value")?.clone());
                i += 2;
            }
            "--list-rules" => {
                opts.list_rules = true;
                i += 1;
            }
            other => bail!("unknown option `{other}`"),
        }
    }
    Ok(opts)
}

fn cmd_lint(opts: &LintOpts, out: &mut impl std::io::Write) -> Result<()> {
    if opts.list_rules {
        for (rule, what) in analysis::RULES {
            writeln!(out, "{rule:<24} {what}")?;
        }
        return Ok(());
    }
    let root = match &opts.root {
        Some(dir) => {
            let p = std::path::PathBuf::from(dir);
            if !p.join("rust").join("src").is_dir() {
                bail!("--root {dir}: no rust/src directory underneath");
            }
            p
        }
        None => analysis::find_root(&std::env::current_dir().context("lint: no cwd")?)?,
    };
    let report = analysis::run_lint(&root)?;
    for d in &report.diagnostics {
        writeln!(out, "{}", d.render())?;
    }
    if report.is_clean() {
        writeln!(
            out,
            "lint clean: {} files scanned, {} justified suppression(s)",
            report.files_scanned, report.suppressions_honored
        )?;
        Ok(())
    } else {
        bail!(
            "lint: {} diagnostic(s) across {} scanned files",
            report.diagnostics.len(),
            report.files_scanned
        );
    }
}

fn cmd_apps(out: &mut impl std::io::Write) -> Result<()> {
    writeln!(
        out,
        "{:<14} {:<20} {:<28} {}",
        "Application", "Dataset", "Model", "SLO"
    )?;
    let apps: Vec<Box<dyn Application>> = vec![
        Box::new(Chatbot::new(0, 1)),
        Box::new(DeepResearch::new(0, 1)),
        Box::new(ImageGen::new(0, 1)),
        Box::new(LiveCaptions::new(0, 1)),
    ];
    for app in &apps {
        writeln!(
            out,
            "{:<14} {:<20} {:<28} {}",
            app.name(),
            app.dataset_name(),
            app.model_name(),
            app.slo().describe()
        )?;
    }
    Ok(())
}

fn cmd_validate(path: &str, out: &mut impl std::io::Write) -> Result<()> {
    let cfg = BenchConfig::load(path)?;
    let dag = Dag::build(&cfg.workflow)?;
    writeln!(
        out,
        "OK: {} tasks, {} workflow nodes (depth {}), {} servers, strategy {:?}",
        cfg.tasks.len(),
        dag.len(),
        dag.depth(),
        cfg.servers.len(),
        cfg.strategy
    )?;
    Ok(())
}

fn cmd_run(path: &str, opts: &RunOpts, out: &mut impl std::io::Write) -> Result<()> {
    let cfg = BenchConfig::load(path)?;
    let runtime = match (&opts.artifacts, opts.no_pjrt) {
        (Some(dir), false) if Runtime::available(dir) => {
            writeln!(out, "loading AOT artifacts from {dir} …")?;
            Some(Runtime::load_dir(dir)?)
        }
        _ => {
            writeln!(out, "running simulation-only (no artifacts)")?;
            None
        }
    };
    let result = ScenarioRunner::new(&cfg, runtime)?.run()?;
    let report = generate(&result);
    writeln!(out, "{}", report.text)?;
    if let Some(csv_path) = &opts.csv {
        std::fs::write(csv_path, to_csv(&result))
            .with_context(|| format!("writing {csv_path}"))?;
        writeln!(out, "wrote per-request CSV to {csv_path}")?;
    }
    if let Some(json_path) = &opts.json {
        std::fs::write(json_path, to_json_summary(&result, &report.monitor))
            .with_context(|| format!("writing {json_path}"))?;
        writeln!(out, "wrote JSON run summary to {json_path}")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(args: &[&str]) -> (Result<()>, String) {
        let args: Vec<String> = args.iter().map(|s| s.to_string()).collect();
        let mut buf = Vec::new();
        let r = run_cli(&args, &mut buf);
        (r, String::from_utf8(buf).unwrap())
    }

    #[test]
    fn no_args_prints_usage() {
        let (r, out) = run(&[]);
        assert!(r.is_ok());
        assert!(out.contains("USAGE"));
    }

    #[test]
    fn apps_lists_table1() {
        let (r, out) = run(&["apps"]);
        assert!(r.is_ok());
        for needle in ["Chatbot", "DeepResearch", "ImageGen", "LiveCaptions", "Earnings-21"] {
            assert!(out.contains(needle), "missing {needle} in:\n{out}");
        }
    }

    #[test]
    fn unknown_command_errors() {
        let (r, _) = run(&["frobnicate"]);
        assert!(r.is_err());
    }

    #[test]
    fn validate_and_run_config_file() {
        let dir = std::env::temp_dir().join("cb_cli_test");
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = dir.join("cfg.yaml");
        std::fs::write(&cfg, "Chat (chatbot):\n  num_requests: 1\n").unwrap();
        let (r, out) = run(&["validate", cfg.to_str().unwrap()]);
        assert!(r.is_ok(), "{out}");
        assert!(out.contains("OK: 1 tasks"));

        let csv = dir.join("out.csv");
        let json = dir.join("out.json");
        let (r, out) = run(&[
            "run",
            cfg.to_str().unwrap(),
            "--no-pjrt",
            "--csv",
            csv.to_str().unwrap(),
            "--json",
            json.to_str().unwrap(),
        ]);
        assert!(r.is_ok(), "{out}");
        assert!(out.contains("ConsumerBench report"));
        assert!(csv.is_file());
        let summary = std::fs::read_to_string(&json).unwrap();
        assert!(summary.contains("\"consumerbench_run\": 1"), "{summary}");
    }

    #[test]
    fn bad_option_rejected() {
        let (r, _) = run(&["run", "x.yaml", "--frob"]);
        assert!(r.is_err());
    }

    #[test]
    fn scenario_list_names_matrix() {
        let (r, out) = run(&["scenario", "--list"]);
        assert!(r.is_ok(), "{out}");
        assert!(out.contains("68 scenarios"), "{out}");
        assert!(out.contains("mix=chat/policy=greedy/arrival=closed/testbed=intel_server"));
        assert!(out.contains("policy=fair_share"));
        assert!(out.contains("arrival=poisson"));
        assert!(out.contains("server=adaptive"));
        // The workflow axis: every shape, including the slo_aware slice.
        assert!(out.contains("workflow=pipeline/policy=greedy"), "{out}");
        assert!(out.contains("workflow=content_creation/policy=slo_aware"), "{out}");
        // The backend-ablation slice: every kernel implementation.
        assert!(out.contains("backend=tuned_native/mix=chat+imagegen"), "{out}");
        assert!(out.contains("backend=generic_torch/mix=captions+imagegen"), "{out}");
        assert!(out.contains("backend=fused_custom/"), "{out}");
        // The chaos slice: every fault class, in static/adaptive pairs.
        assert!(out.contains("chaos=thermal_throttle/mix=chat+imagegen/policy=slo_aware"), "{out}");
        assert!(out.contains("chaos=server_crash/"), "{out}");
        assert!(out.contains("chaos=pcie_degrade/"), "{out}");
    }

    #[test]
    fn scenario_chaos_flag_filters_the_slice() {
        // `--chaos thermal_throttle` keeps exactly its static/adaptive pair.
        let (r, out) = run(&["scenario", "--list", "--chaos", "thermal_throttle"]);
        assert!(r.is_ok(), "{out}");
        assert!(out.contains("2 scenarios"), "{out}");
        assert!(!out.contains("chaos=server_crash"), "{out}");
        assert!(!out.contains("mix=chat/"), "{out}");
        // Composes with --filter.
        let (r, out) = run(&[
            "scenario",
            "--list",
            "--filter",
            "server=adaptive",
            "--chaos",
            "suspend",
        ]);
        assert!(r.is_ok(), "{out}");
        assert!(out.contains("1 scenarios"), "{out}");
        // Unknown fault class is rejected; a chaos filter that matches
        // nothing is an error, not an empty sweep.
        let (r, _) = run(&["scenario", "--list", "--chaos", "gamma_rays"]);
        assert!(r.is_err());
        let (r, _) = run(&[
            "scenario",
            "--list",
            "--filter",
            "mix=chat/",
            "--chaos",
            "suspend",
        ]);
        assert!(r.is_err(), "flat chat scenarios are fault-free");
        let (r, _) = run(&["scenario", "--chaos"]);
        assert!(r.is_err(), "--chaos without a value must be rejected");
    }

    #[test]
    fn scenario_backend_flag_filters_the_slice() {
        // `--backend generic_torch` keeps exactly the generic ablation
        // scenarios (everything else runs tuned_native).
        let (r, out) = run(&["scenario", "--list", "--backend", "generic_torch"]);
        assert!(r.is_ok(), "{out}");
        assert!(out.contains("2 scenarios"), "{out}");
        assert!(!out.contains("tuned_native"), "{out}");
        assert!(!out.contains("mix=chat/"), "{out}");
        // `--backend tuned_native` keeps the whole tuned matrix (flat +
        // workflow + the tuned member of the ablation trio).
        let (r, out) = run(&["scenario", "--list", "--backend", "tuned_native"]);
        assert!(r.is_ok(), "{out}");
        assert!(out.contains("64 scenarios"), "{out}");
        // Composes with --filter.
        let (r, out) = run(&[
            "scenario",
            "--list",
            "--filter",
            "backend=",
            "--backend",
            "fused_custom",
        ]);
        assert!(r.is_ok(), "{out}");
        assert!(out.contains("2 scenarios"), "{out}");
        // Unknown backend is rejected; a backend that filters to nothing is
        // an error, not an empty sweep.
        let (r, _) = run(&["scenario", "--list", "--backend", "npu"]);
        assert!(r.is_err());
        let (r, _) = run(&[
            "scenario",
            "--list",
            "--filter",
            "mix=chat/",
            "--backend",
            "generic_torch",
        ]);
        assert!(r.is_err(), "flat chat scenarios are all tuned");
        let (r, _) = run(&["scenario", "--backend"]);
        assert!(r.is_err(), "--backend without a value must be rejected");
    }

    #[test]
    fn scenario_filter_selects_the_workflow_slice() {
        let (r, out) = run(&["scenario", "--list", "--filter", "workflow"]);
        assert!(r.is_ok(), "{out}");
        assert!(out.contains("10 scenarios"), "{out}");
        assert!(!out.contains("mix="), "{out}");
        for shape in ["pipeline", "fanout", "diamond", "content_creation"] {
            assert!(out.contains(&format!("workflow={shape}")), "{out}");
        }
    }

    #[test]
    fn scenario_filter_narrows_the_matrix() {
        let (r, out) = run(&["scenario", "--list", "--filter", "server=adaptive"]);
        assert!(r.is_ok(), "{out}");
        assert!(
            out.contains("25 scenarios"),
            "18 flat + 2 content_creation + 5 chaos: {out}"
        );
        assert!(!out.contains("server=static"), "{out}");

        let (r, out) = run(&[
            "scenario",
            "--list",
            "--filter",
            "mix=captions+imagegen/policy=greedy/",
        ]);
        assert!(r.is_ok(), "{out}");
        // 2 flat (closed/poisson) + the 3 backend-ablation runs of the mix
        // (their names embed the same mix/policy segment).
        assert!(out.contains("5 scenarios"), "{out}");

        // A filter that matches nothing is an error, not an empty sweep.
        let (r, _) = run(&["scenario", "--list", "--filter", "mix=nonexistent"]);
        assert!(r.is_err());
        let (r, _) = run(&["scenario", "--filter"]);
        assert!(r.is_err(), "--filter without a value must be rejected");
    }

    #[test]
    fn scenario_filter_runs_only_the_subset() {
        let dir = std::env::temp_dir().join("cb_scenario_filter_run");
        std::fs::create_dir_all(&dir).unwrap();
        let json_path = dir.join("subset.json");
        let (r, out) = run(&[
            "scenario",
            "--filter",
            "mix=chat/policy=greedy/arrival=closed/testbed=intel_server/server=static",
            "--out",
            json_path.to_str().unwrap(),
        ]);
        assert!(r.is_ok(), "{out}");
        let json = std::fs::read_to_string(&json_path).unwrap();
        assert!(json.contains("\"num_scenarios\": 1"), "{json}");
        assert!(json.contains("\"server_mode\": \"static\""));
    }

    #[test]
    fn scenario_dump_writes_configs() {
        let dir = std::env::temp_dir().join("cb_scenario_dump");
        let _ = std::fs::remove_dir_all(&dir);
        let (r, out) = run(&["scenario", "--dump", dir.to_str().unwrap()]);
        assert!(r.is_ok(), "{out}");
        let n = std::fs::read_dir(&dir).unwrap().count();
        assert_eq!(n, 68, "expected 68 dumped configs");
    }

    #[test]
    fn scenario_runs_default_matrix_to_json() {
        // The acceptance path: one invocation expands and executes the full
        // default matrix (>= 20 scenarios, all three policies, open-loop
        // Poisson and the static/adaptive serving ablation included) and
        // emits the aggregate JSON report.
        let dir = std::env::temp_dir().join("cb_scenario_run");
        std::fs::create_dir_all(&dir).unwrap();
        let json_path = dir.join("report.json");
        let (r, out) = run(&[
            "scenario",
            "--seed",
            "42",
            "--out",
            json_path.to_str().unwrap(),
        ]);
        assert!(r.is_ok(), "{out}");
        assert!(
            out.contains("policies covered: greedy, partition, fair_share, slo_aware"),
            "{out}"
        );
        let json = std::fs::read_to_string(&json_path).unwrap();
        assert!(json.contains("\"num_scenarios\": 68"));
        assert!(json.contains("\"arrival\": \"poisson\""));
        assert!(json.contains("\"mix\": \"full-stack\""));
        assert!(json.contains("\"server_mode\": \"adaptive\""));
        assert!(json.contains("\"adaptive_vs_static\""));
        // Workflow scenarios land in the same report with their e2e and
        // critical-path columns, and the per-strategy e2e comparison.
        assert!(json.contains("\"workflow\": \"content_creation\""));
        assert!(json.contains("\"critical_path\""));
        assert!(json.contains("\"e2e_latency_s\""));
        assert!(json.contains("\"workflows\": ["));
        // The backend-ablation slice lands with its column and summary.
        assert!(json.contains("\"backend\": \"generic_torch\""));
        assert!(json.contains("\"backends\": ["));
        assert!(json.contains("\"mean_throughput_rps\""));
        // The chaos slice lands with its column and summary section.
        assert!(json.contains("\"chaos\": \"server_crash\""));
        assert!(json.contains("\"chaos\": ["));
    }

    #[test]
    fn lint_list_rules_prints_registry() {
        let (r, out) = run(&["lint", "--list-rules"]);
        assert!(r.is_ok(), "{out}");
        for rule in [
            "no-unordered-iteration",
            "no-wall-clock",
            "no-poisonable-unwrap",
            "no-float-order-hazard",
            "no-ambient-entropy",
            "pin-drift",
            "bad-suppression",
        ] {
            assert!(out.contains(rule), "missing {rule} in:\n{out}");
        }
    }

    #[test]
    fn lint_bad_options_rejected() {
        let (r, _) = run(&["lint", "--frob"]);
        assert!(r.is_err());
        let (r, _) = run(&["lint", "--root"]);
        assert!(r.is_err(), "--root without a value must be rejected");
        let (r, _) = run(&["lint", "--root", "/nonexistent/definitely-not-a-repo"]);
        assert!(r.is_err(), "--root must point at a repository root");
    }

    #[test]
    fn scenario_bad_option_rejected() {
        let (r, _) = run(&["scenario", "--warp"]);
        assert!(r.is_err());
        let (r, _) = run(&["scenario", "--seed", "notanumber"]);
        assert!(r.is_err());
    }

    #[test]
    fn scenario_jobs_flag_validated() {
        let (r, _) = run(&["scenario", "--jobs", "0"]);
        assert!(r.is_err(), "--jobs 0 must be rejected");
        let (r, _) = run(&["scenario", "--jobs", "many"]);
        assert!(r.is_err());
        let (r, _) = run(&["scenario", "--jobs"]);
        assert!(r.is_err(), "--jobs without a value must be rejected");
        // A valid jobs value parses (use --list so nothing executes).
        let (r, out) = run(&["scenario", "--jobs", "4", "--list"]);
        assert!(r.is_ok(), "{out}");
    }

    #[test]
    fn scenario_queue_and_trace_mode_flags_validated() {
        // Unknown values and orphan --trace-window are rejected.
        let (r, _) = run(&["scenario", "--list", "--queue", "splay_tree"]);
        assert!(r.is_err());
        let (r, _) = run(&["scenario", "--queue"]);
        assert!(r.is_err(), "--queue without a value must be rejected");
        let (r, _) = run(&["scenario", "--list", "--trace-mode", "ring"]);
        assert!(r.is_err());
        let (r, _) = run(&["scenario", "--list", "--trace-window", "64"]);
        assert!(r.is_err(), "--trace-window without streaming must be rejected");
        let (r, _) = run(&[
            "scenario", "--list", "--trace-mode", "full", "--trace-window", "64",
        ]);
        assert!(r.is_err(), "--trace-window under full mode must be rejected");
        let (r, _) = run(&[
            "scenario", "--list", "--trace-mode", "streaming", "--trace-window", "0",
        ]);
        assert!(r.is_err(), "zero window must be rejected");
        // Valid combinations parse; the overrides land in dumped configs
        // (flag order does not matter for --trace-window).
        let dir = std::env::temp_dir().join("cb_scenario_queue_dump");
        let _ = std::fs::remove_dir_all(&dir);
        let (r, out) = run(&[
            "scenario",
            "--filter",
            "mix=chat/policy=greedy/arrival=closed/testbed=intel_server",
            "--queue",
            "wheel",
            "--trace-window",
            "64",
            "--trace-mode",
            "streaming",
            "--dump",
            dir.to_str().unwrap(),
        ]);
        assert!(r.is_ok(), "{out}");
        let mut dumped = 0;
        for entry in std::fs::read_dir(&dir).unwrap() {
            let text = std::fs::read_to_string(entry.unwrap().path()).unwrap();
            assert!(text.contains("event_queue: wheel\n"), "{text}");
            assert!(text.contains("trace_mode: streaming\ntrace_window: 64\n"), "{text}");
            dumped += 1;
        }
        assert!(dumped > 0);
    }

    #[test]
    fn scenario_supervision_flags_validated() {
        let (r, _) = run(&["scenario", "--resume"]);
        assert!(r.is_err(), "--resume without --journal must be rejected");
        let (r, _) = run(&["scenario", "--journal"]);
        assert!(r.is_err(), "--journal without a value must be rejected");
        let (r, _) = run(&["scenario", "--watchdog-secs", "0"]);
        assert!(r.is_err());
        let (r, _) = run(&["scenario", "--watchdog-secs", "soon"]);
        assert!(r.is_err());
        let (r, _) = run(&["scenario", "--inject-panic"]);
        assert!(r.is_err(), "--inject-panic without a value must be rejected");
        // An injection substring that matches nothing is an error, not a
        // silently fault-free sweep.
        let (r, _) = run(&["scenario", "--list", "--inject-panic", "mix=nonexistent"]);
        assert!(r.is_err());
    }

    #[test]
    fn scenario_injected_failure_quarantines_and_exits_nonzero() {
        let dir = std::env::temp_dir().join("cb_scenario_inject");
        std::fs::create_dir_all(&dir).unwrap();
        let json_path = dir.join("report.json");
        let (r, out) = run(&[
            "scenario",
            "--filter",
            "mix=chat/policy=greedy/arrival=closed/testbed=intel_server",
            "--inject-panic",
            "server=static",
            "--out",
            json_path.to_str().unwrap(),
        ]);
        assert!(r.is_err(), "a quarantined row must exit nonzero: {out}");
        // The report is still written, with the sibling completed and the
        // failure taxonomized.
        let json = std::fs::read_to_string(&json_path).unwrap();
        assert!(json.contains("\"status\": \"panicked\""), "{json}");
        assert!(json.contains("\"status\": \"ok\""), "sibling completed: {json}");
        assert!(json.contains("\"failures\": {"), "{json}");
        assert!(json.contains("\"panicked\": 1"), "{json}");
    }

    #[test]
    fn scenario_fail_fast_aborts_without_a_report() {
        let dir = std::env::temp_dir().join("cb_scenario_failfast");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let json_path = dir.join("report.json");
        let (r, _) = run(&[
            "scenario",
            "--filter",
            "mix=chat/policy=greedy/arrival=closed/testbed=intel_server",
            "--inject-error",
            "server=static",
            "--fail-fast",
            "--out",
            json_path.to_str().unwrap(),
        ]);
        assert!(r.is_err(), "fail-fast must abort with an error");
        assert!(!json_path.exists(), "fail-fast must not write a report");
    }

    #[test]
    fn fleet_list_prints_device_table() {
        let (r, out) = run(&["fleet", "--list", "--devices", "12", "--seed", "7"]);
        assert!(r.is_ok(), "{out}");
        assert!(out.contains("device-00000"), "{out}");
        assert!(out.contains("device-00011"), "{out}");
        assert!(out.contains("12 devices"), "{out}");
        // Same seed, same table.
        let (_, again) = run(&["fleet", "--list", "--devices", "12", "--seed", "7"]);
        assert_eq!(out, again);
        // Different seed, different table.
        let (_, other) = run(&["fleet", "--list", "--devices", "12", "--seed", "8"]);
        assert_ne!(out, other);
    }

    #[test]
    fn fleet_bad_options_rejected() {
        assert!(run(&["fleet", "--mix", "quantum"]).0.is_err());
        assert!(run(&["fleet", "--strategy", "psychic"]).0.is_err());
        assert!(run(&["fleet", "--shard-size", "0"]).0.is_err());
        assert!(run(&["fleet", "--trace-window", "0"]).0.is_err());
        assert!(run(&["fleet", "--devices", "0"]).0.is_err());
        assert!(run(&["fleet", "--resume"]).0.is_err());
        assert!(run(&["fleet", "--wat"]).0.is_err());
    }

    #[test]
    fn fleet_runs_small_population_to_json() {
        let dir = std::env::temp_dir().join("cb_fleet_cli");
        std::fs::create_dir_all(&dir).unwrap();
        let json_path = dir.join("fleet.json");
        let (r, out) = run(&[
            "fleet",
            "--devices",
            "6",
            "--seed",
            "11",
            "--shard-size",
            "3",
            "--jobs",
            "2",
            "--out",
            json_path.to_str().unwrap(),
        ]);
        assert!(r.is_ok(), "{out}");
        assert!(out.contains("sweeping 6 devices in 2 shards"), "{out}");
        assert!(out.contains("status: ok"), "{out}");
        let json = std::fs::read_to_string(&json_path).unwrap();
        assert!(json.starts_with("{\n  \"consumerbench_fleet\": 1,"), "{json}");
        assert!(json.contains("\"devices\": {\"total\": 6"), "{json}");
        assert!(json.contains("\"aggregation\": {"), "{json}");
    }

    #[test]
    fn fleet_population_file_round_trips() {
        let dir = std::env::temp_dir().join("cb_fleet_popfile");
        std::fs::create_dir_all(&dir).unwrap();
        let pop_path = dir.join("pop.yaml");
        std::fs::write(
            &pop_path,
            "population:\n  name: offices\n  count: 5\n  seed: 3\n  classes:\n    laptop: 1.0\n",
        )
        .unwrap();
        let (r, out) = run(&[
            "fleet",
            "--list",
            "--population",
            pop_path.to_str().unwrap(),
        ]);
        assert!(r.is_ok(), "{out}");
        assert!(out.contains("5 devices"), "{out}");
        // An all-laptop population lists only laptops.
        assert!(out.contains("laptop"), "{out}");
        assert!(!out.contains("desktop"), "{out}");
        assert!(!out.contains("edge"), "{out}");
    }
}
