//! Command-line interface for the `consumerbench` binary.
//!
//! Hand-rolled argument parsing (the offline crate set has no `clap`):
//!
//! ```text
//! consumerbench run <config.yaml> [--artifacts DIR] [--csv FILE] [--no-pjrt]
//! consumerbench validate <config.yaml>
//! consumerbench apps
//! consumerbench help
//! ```

use anyhow::{bail, Context, Result};

use crate::apps::{Application, Chatbot, DeepResearch, ImageGen, LiveCaptions};
use crate::coordinator::{generate, to_csv, BenchConfig, Dag, ScenarioRunner};
use crate::runtime::Runtime;

const USAGE: &str = "\
ConsumerBench — benchmarking generative AI applications on end-user devices

USAGE:
    consumerbench run <config.yaml> [--artifacts DIR] [--csv FILE] [--no-pjrt]
    consumerbench validate <config.yaml>
    consumerbench apps
    consumerbench help

COMMANDS:
    run        Execute a workflow configuration and print the benchmark report
    validate   Parse the configuration and check the workflow DAG
    apps       List the built-in applications (paper Table 1)

OPTIONS:
    --artifacts DIR   AOT artifact directory (default: artifacts)
    --csv FILE        Also write per-request metrics as CSV
    --no-pjrt         Skip real-numerics PJRT execution even if artifacts exist
";

/// Entry point used by `main.rs`.
pub fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    run_cli(&args, &mut std::io::stdout())
}

/// Testable CLI core.
pub fn run_cli(args: &[String], out: &mut impl std::io::Write) -> Result<()> {
    let Some(cmd) = args.first() else {
        writeln!(out, "{USAGE}")?;
        return Ok(());
    };
    match cmd.as_str() {
        "help" | "--help" | "-h" => {
            writeln!(out, "{USAGE}")?;
            Ok(())
        }
        "apps" => cmd_apps(out),
        "validate" => {
            let path = args.get(1).context("validate: missing <config.yaml>")?;
            cmd_validate(path, out)
        }
        "run" => {
            let path = args.get(1).context("run: missing <config.yaml>")?;
            let opts = parse_opts(&args[2..])?;
            cmd_run(path, &opts, out)
        }
        other => bail!("unknown command `{other}`\n{USAGE}"),
    }
}

#[derive(Debug, Default)]
struct RunOpts {
    artifacts: Option<String>,
    csv: Option<String>,
    no_pjrt: bool,
}

fn parse_opts(args: &[String]) -> Result<RunOpts> {
    let mut opts = RunOpts {
        artifacts: Some("artifacts".to_string()),
        ..Default::default()
    };
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--artifacts" => {
                opts.artifacts = Some(
                    args.get(i + 1)
                        .context("--artifacts requires a value")?
                        .clone(),
                );
                i += 2;
            }
            "--csv" => {
                opts.csv = Some(args.get(i + 1).context("--csv requires a value")?.clone());
                i += 2;
            }
            "--no-pjrt" => {
                opts.no_pjrt = true;
                i += 1;
            }
            other => bail!("unknown option `{other}`"),
        }
    }
    Ok(opts)
}

fn cmd_apps(out: &mut impl std::io::Write) -> Result<()> {
    writeln!(
        out,
        "{:<14} {:<20} {:<28} {}",
        "Application", "Dataset", "Model", "SLO"
    )?;
    let apps: Vec<Box<dyn Application>> = vec![
        Box::new(Chatbot::new(0, 1)),
        Box::new(DeepResearch::new(0, 1)),
        Box::new(ImageGen::new(0, 1)),
        Box::new(LiveCaptions::new(0, 1)),
    ];
    for app in &apps {
        writeln!(
            out,
            "{:<14} {:<20} {:<28} {}",
            app.name(),
            app.dataset_name(),
            app.model_name(),
            app.slo().describe()
        )?;
    }
    Ok(())
}

fn cmd_validate(path: &str, out: &mut impl std::io::Write) -> Result<()> {
    let cfg = BenchConfig::load(path)?;
    let dag = Dag::build(&cfg.workflow)?;
    writeln!(
        out,
        "OK: {} tasks, {} workflow nodes (depth {}), {} servers, strategy {:?}",
        cfg.tasks.len(),
        dag.len(),
        dag.depth(),
        cfg.servers.len(),
        cfg.strategy
    )?;
    Ok(())
}

fn cmd_run(path: &str, opts: &RunOpts, out: &mut impl std::io::Write) -> Result<()> {
    let cfg = BenchConfig::load(path)?;
    let runtime = match (&opts.artifacts, opts.no_pjrt) {
        (Some(dir), false) if Runtime::available(dir) => {
            writeln!(out, "loading AOT artifacts from {dir} …")?;
            Some(Runtime::load_dir(dir)?)
        }
        _ => {
            writeln!(out, "running simulation-only (no artifacts)")?;
            None
        }
    };
    let result = ScenarioRunner::new(&cfg, runtime)?.run()?;
    let report = generate(&result);
    writeln!(out, "{}", report.text)?;
    if let Some(csv_path) = &opts.csv {
        std::fs::write(csv_path, to_csv(&result))
            .with_context(|| format!("writing {csv_path}"))?;
        writeln!(out, "wrote per-request CSV to {csv_path}")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(args: &[&str]) -> (Result<()>, String) {
        let args: Vec<String> = args.iter().map(|s| s.to_string()).collect();
        let mut buf = Vec::new();
        let r = run_cli(&args, &mut buf);
        (r, String::from_utf8(buf).unwrap())
    }

    #[test]
    fn no_args_prints_usage() {
        let (r, out) = run(&[]);
        assert!(r.is_ok());
        assert!(out.contains("USAGE"));
    }

    #[test]
    fn apps_lists_table1() {
        let (r, out) = run(&["apps"]);
        assert!(r.is_ok());
        for needle in ["Chatbot", "DeepResearch", "ImageGen", "LiveCaptions", "Earnings-21"] {
            assert!(out.contains(needle), "missing {needle} in:\n{out}");
        }
    }

    #[test]
    fn unknown_command_errors() {
        let (r, _) = run(&["frobnicate"]);
        assert!(r.is_err());
    }

    #[test]
    fn validate_and_run_config_file() {
        let dir = std::env::temp_dir().join("cb_cli_test");
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = dir.join("cfg.yaml");
        std::fs::write(&cfg, "Chat (chatbot):\n  num_requests: 1\n").unwrap();
        let (r, out) = run(&["validate", cfg.to_str().unwrap()]);
        assert!(r.is_ok(), "{out}");
        assert!(out.contains("OK: 1 tasks"));

        let csv = dir.join("out.csv");
        let (r, out) = run(&[
            "run",
            cfg.to_str().unwrap(),
            "--no-pjrt",
            "--csv",
            csv.to_str().unwrap(),
        ]);
        assert!(r.is_ok(), "{out}");
        assert!(out.contains("ConsumerBench report"));
        assert!(csv.is_file());
    }

    #[test]
    fn bad_option_rejected() {
        let (r, _) = run(&["run", "x.yaml", "--frob"]);
        assert!(r.is_err());
    }
}
