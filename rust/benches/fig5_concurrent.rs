//! Fig. 5: concurrent execution of Chatbot + ImageGen + LiveCaptions under
//! greedy allocation vs static GPU partitioning (NVIDIA MPS, 33% each).
//!
//! Paper shape (5a): greedy leaves ImageGen at its exclusive performance but
//! starves LiveCaptions (≈12x mean e2e, SLOs missed for almost all
//! segments, decode ≈30x slower — 5b); partitioning degrades everyone
//! gracefully — LiveCaptions recovers, ImageGen narrowly misses its step
//! SLO, and the SMACT timeline shows the stairstep under-utilization.

#[path = "common.rs"]
mod common;
use common::{header, mean_component, monitor, print_app_row, run, util_row};

fn config(strategy: &str) -> String {
    format!(
        "\
Chat (chatbot):
  num_requests: 10
  device: gpu
  slo: [1s, 0.25s]
Image (imagegen):
  num_requests: 35
  device: gpu
  slo: 1s
Captions (livecaptions):
  num_requests: 75
  device: gpu
  slo: 2s
strategy: {strategy}
seed: 42
"
    )
}

/// LiveCaptions exclusive-GPU baselines for the slowdown factors.
fn exclusive_lc() -> (f64, f64) {
    let result = run("Captions (livecaptions):\n  num_requests: 75\n  device: gpu\n  slo: 2s\nseed: 42\n");
    let node = &result.nodes[0];
    let mean_lat: f64 =
        node.metrics.iter().map(|m| m.latency).sum::<f64>() / node.metrics.len() as f64;
    (mean_lat, mean_component(node, "decode_time"))
}

fn main() {
    let (lc_excl_lat, lc_excl_decode) = exclusive_lc();
    for strategy in ["greedy", "partition"] {
        header(&format!("Fig. 5a: {strategy}"));
        let result = run(&config(strategy));
        for node in &result.nodes {
            print_app_row(&node.id, node);
        }
        let lc = result.node("Captions (livecaptions)").unwrap();
        let mean_lat: f64 =
            lc.metrics.iter().map(|m| m.latency).sum::<f64>() / lc.metrics.len() as f64;
        let mean_decode = mean_component(lc, "decode_time");
        println!(
            "  Fig. 5b LiveCaptions: e2e {:.1}x exclusive, decode {:.1}x exclusive",
            mean_lat / lc_excl_lat,
            mean_decode / lc_excl_decode
        );
        let mon = monitor(&result);
        util_row("SMACT", &mon.gpu_smact);
        util_row("SMOCC", &mon.gpu_smocc);
    }
    println!(
        "\npaper shape: greedy — ImageGen ≈ exclusive, LiveCaptions ≈12x e2e\n\
         (decode ≈30x) and misses almost all SLOs; partition — LiveCaptions\n\
         recovers, ImageGen narrowly misses 1s/step, stairstep SMACT."
    );
}
