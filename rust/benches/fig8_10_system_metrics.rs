//! Figs. 8, 9, 10 (appendix): full system-metric panels — GPU utilization,
//! memory bandwidth, VRAM, power (Fig. 8, exclusive GPU); CPU utilization,
//! DRAM bandwidth, CPU power (Fig. 9, exclusive CPU); and the concurrent
//! greedy-vs-partition energy comparison (Fig. 10).
//!
//! Paper shape: Chatbot drives the most GPU memory bandwidth (decode is
//! bandwidth-bound); ImageGen holds the most VRAM; peak GPU power is
//! similar across apps despite very different SMOCC. On the CPU, apps are
//! compute-bound (high core util, modest DRAM bandwidth) and draw far less
//! power. Concurrent greedy consumes more average power than partitioning
//! (which under-utilizes the device).

#[path = "common.rs"]
mod common;
use common::{header, monitor, run};

fn exclusive(app: &str, device: &str, n: usize) -> String {
    format!("App ({app}):\n  num_requests: {n}\n  device: {device}\nseed: 42\n")
}

fn main() {
    header("Fig. 8: exclusive GPU — bandwidth / VRAM / power");
    println!(
        "  {:<14} {:>9} {:>10} {:>11} {:>11}",
        "app", "mem-BW", "peak VRAM", "mean power", "peak power"
    );
    for (label, app, n) in [
        ("Chatbot", "chatbot", 8usize),
        ("ImageGen", "imagegen", 6),
        ("LiveCaptions", "livecaptions", 30),
    ] {
        let result = run(&exclusive(app, "gpu", n));
        let mon = monitor(&result);
        let busy_bw: Vec<f64> = mon
            .gpu_bw
            .values()
            .iter()
            .copied()
            .filter(|&v| v > 1e-6)
            .collect();
        let mean_bw = busy_bw.iter().sum::<f64>() / busy_bw.len().max(1) as f64;
        let busy_pw: Vec<f64> = mon
            .gpu_power
            .values()
            .iter()
            .copied()
            .filter(|&v| v > 60.0) // above idle
            .collect();
        let mean_pw = busy_pw.iter().sum::<f64>() / busy_pw.len().max(1) as f64;
        println!(
            "  {:<14} {:>8.1}% {:>8.1}GiB {:>10.0}W {:>10.0}W",
            label,
            mean_bw * 100.0,
            mon.peak_vram_gib(),
            mean_pw,
            mon.gpu_power.max(),
        );
    }

    header("Fig. 9: exclusive CPU — utilization / DRAM BW / power");
    println!(
        "  {:<14} {:>9} {:>10} {:>11}",
        "app", "CPU util", "DRAM BW", "peak power"
    );
    for (label, app, n) in [
        ("Chatbot", "chatbot", 4usize),
        ("ImageGen", "imagegen", 2),
        ("LiveCaptions", "livecaptions", 5),
    ] {
        let result = run(&exclusive(app, "cpu", n));
        let mon = monitor(&result);
        let busy: Vec<f64> = mon
            .cpu_util
            .values()
            .iter()
            .copied()
            .filter(|&v| v > 1e-6)
            .collect();
        let mean_util = busy.iter().sum::<f64>() / busy.len().max(1) as f64;
        let busy_bw: Vec<f64> = mon
            .dram_bw
            .values()
            .iter()
            .copied()
            .filter(|&v| v > 1e-6)
            .collect();
        let mean_bw = busy_bw.iter().sum::<f64>() / busy_bw.len().max(1) as f64;
        println!(
            "  {:<14} {:>8.1}% {:>9.1}% {:>10.0}W",
            label,
            mean_util * 100.0,
            mean_bw * 100.0,
            mon.cpu_power.max(),
        );
    }

    header("Fig. 10: concurrent execution — energy, greedy vs partition");
    for strategy in ["greedy", "partition"] {
        let cfg = format!(
            "\
Chat (chatbot):
  num_requests: 8
  device: gpu
Image (imagegen):
  num_requests: 15
  device: gpu
Captions (livecaptions):
  num_requests: 40
  device: gpu
strategy: {strategy}
seed: 42
"
        );
        let result = run(&cfg);
        let mon = monitor(&result);
        let busy_pw: Vec<f64> = mon
            .gpu_power
            .values()
            .iter()
            .copied()
            .filter(|&v| v > 60.0)
            .collect();
        let mean_pw = busy_pw.iter().sum::<f64>() / busy_pw.len().max(1) as f64;
        println!(
            "  {:<10} mean GPU power {:>5.0} W   GPU energy {:>8.0} J   SMACT(busy) {:>5.1}%   makespan {:>6.1}s",
            strategy,
            mean_pw,
            mon.gpu_energy(),
            mon.mean_busy_smact() * 100.0,
            result.makespan
        );
    }
    println!(
        "\npaper shape: Chatbot highest BW, ImageGen highest VRAM, similar\n\
         peak powers; CPU runs compute-bound at much lower power; greedy\n\
         draws more average power than the under-utilized partition."
    );
}
