//! §Perf: L3 hot-path throughput — simulated kernel-events per second on the
//! discrete-event engine, and end-to-end scenario wallclock.
//!
//! Target (DESIGN.md §8): ≥1M kernel-events/sec so no figure bench takes
//! more than ~10 s of wallclock.

use std::time::Instant;

use consumerbench::coordinator::run_config_text;

#[path = "common.rs"]
mod common;
use common::engine_events_per_sec;

/// End-to-end scenario wallclock (the Fig. 5 workload).
fn fig5_wallclock() -> f64 {
    let cfg = "\
Chat (chatbot):
  num_requests: 10
  device: gpu
Image (imagegen):
  num_requests: 20
  device: gpu
Captions (livecaptions):
  num_requests: 75
  device: gpu
strategy: greedy
seed: 42
";
    let t0 = Instant::now();
    let r = run_config_text(cfg, None).unwrap();
    let dt = t0.elapsed().as_secs_f64();
    assert!(r.makespan > 0.0);
    dt
}

fn main() {
    use consumerbench::gpusim::engine::{QueueBackend, TraceMode};
    let (eps_traced, _) =
        engine_events_per_sec(QueueBackend::Heap, Some(TraceMode::Full), 2_000, 50);
    let (eps_untraced, _) = engine_events_per_sec(QueueBackend::Heap, None, 2_000, 50);
    let wall = fig5_wallclock();
    println!("=== §Perf: L3 engine hot path ===");
    println!("engine throughput (trace on):  {:>10.0} kernel-events/s", eps_traced);
    println!("engine throughput (trace off): {:>10.0} kernel-events/s", eps_untraced);
    println!("fig5 scenario wallclock:       {:>10.2} s", wall);
    println!("target: >= 1,000,000 events/s traced; fig5 <= 10 s");
}
