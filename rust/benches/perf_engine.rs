//! §Perf: L3 hot-path throughput — simulated kernel-events per second on the
//! discrete-event engine, and end-to-end scenario wallclock.
//!
//! Target (DESIGN.md §8): ≥1M kernel-events/sec so no figure bench takes
//! more than ~10 s of wallclock.

use std::time::Instant;

use consumerbench::coordinator::run_config_text;
use consumerbench::gpusim::engine::{Engine, JobSpec, Phase};
use consumerbench::gpusim::kernel::KernelDesc;
use consumerbench::gpusim::policy::Policy;
use consumerbench::gpusim::profiles::Testbed;

/// Raw engine throughput: N jobs × K kernels with interleaved arrivals.
fn engine_events_per_sec(trace: bool) -> f64 {
    let mut e = Engine::new(Testbed::intel_server(), Policy::Greedy);
    e.set_trace_enabled(trace);
    let clients: Vec<_> = (0..4).map(|i| e.register_client(format!("c{i}"))).collect();
    let kernel = KernelDesc::new("k", 288, 256, 80, 8 * 1024, 1e8, 5e6);
    let jobs = 2_000;
    let kernels_per_job = 50;
    for j in 0..jobs {
        e.submit(
            JobSpec {
                client: clients[j % clients.len()],
                label: format!("j{j}"),
                phases: vec![Phase::gpu("p", 0.0, vec![kernel.clone(); kernels_per_job])],
            },
            j as f64 * 1e-4,
        );
    }
    let events = (jobs * kernels_per_job * 2) as f64; // launch + completion
    let t0 = Instant::now();
    e.run_all();
    let dt = t0.elapsed().as_secs_f64();
    assert_eq!(e.take_completed().len(), jobs);
    events / dt
}

/// End-to-end scenario wallclock (the Fig. 5 workload).
fn fig5_wallclock() -> f64 {
    let cfg = "\
Chat (chatbot):
  num_requests: 10
  device: gpu
Image (imagegen):
  num_requests: 20
  device: gpu
Captions (livecaptions):
  num_requests: 75
  device: gpu
strategy: greedy
seed: 42
";
    let t0 = Instant::now();
    let r = run_config_text(cfg, None).unwrap();
    let dt = t0.elapsed().as_secs_f64();
    assert!(r.makespan > 0.0);
    dt
}

fn main() {
    let eps_traced = engine_events_per_sec(true);
    let eps_untraced = engine_events_per_sec(false);
    let wall = fig5_wallclock();
    println!("=== §Perf: L3 engine hot path ===");
    println!("engine throughput (trace on):  {:>10.0} kernel-events/s", eps_traced);
    println!("engine throughput (trace off): {:>10.0} kernel-events/s", eps_untraced);
    println!("fig5 scenario wallclock:       {:>10.2} s", wall);
    println!("target: >= 1,000,000 events/s traced; fig5 <= 10 s");
}
