//! Fig. 7: end-to-end latency + SLO attainment for the digital
//! content-creation workflow (§4.3) under greedy allocation vs GPU
//! partitioning.
//!
//! Paper shape: greedy finishes the whole workflow ~45% sooner (mainly by
//! letting DeepResearch burst), at the cost of LiveCaptions starvation;
//! partitioning is fair — LiveCaptions is protected, ImageGen runs ~1.8x
//! slower — but the end-to-end time grows.

#[path = "common.rs"]
mod common;
use common::{header, print_app_row, run};

fn config(strategy: &str) -> String {
    format!(
        "\
Brainstorm (chatbot):
  num_requests: 10
  device: gpu
  server: shared_llama
  slo: [1s, 0.25s]
Analysis (deepresearch):
  num_requests: 1
  device: gpu
  server: shared_llama
Preparing Outline (chatbot):
  num_requests: 10
  device: gpu
  slo: [1s, 0.25s]
Creating Cover Art (imagegen):
  num_requests: 10
  device: gpu
  slo: 1s
Generating Captions (livecaptions):
  num_requests: 60
  device: gpu
  slo: 2s
servers:
  shared_llama:
    model: Llama-3.2-3B
    context_window: 131072
    kv_placement: cpu
workflows:
  analysis:
    uses: Analysis (deepresearch)
    background: true
  brainstorm:
    uses: Brainstorm (chatbot)
  outline:
    uses: Preparing Outline (chatbot)
    depend_on: [\"brainstorm\", \"analysis\"]
  cover_art:
    uses: Creating Cover Art (imagegen)
    depend_on: [\"outline\"]
  generate_captions:
    uses: Generating Captions (livecaptions)
    depend_on: [\"outline\"]
strategy: {strategy}
seed: 42
"
    )
}

fn main() {
    let mut makespans = Vec::new();
    let mut img_norms = Vec::new();
    for strategy in ["greedy", "partition"] {
        header(&format!("Fig. 7: content-creation workflow — {strategy}"));
        let result = run(&config(strategy));
        for node in &result.nodes {
            print_app_row(&format!("{} [{:.0}-{:.0}s]", node.id, node.start, node.end), node);
        }
        println!("  workflow end-to-end: {:.1} s", result.makespan);
        makespans.push(result.makespan);
        img_norms.push(result.node("cover_art").unwrap().mean_normalized());
    }
    println!("\n--- headline ---");
    println!(
        "greedy {:.1}s vs partitioned {:.1}s → greedy {:.0}% shorter (paper ~45%)",
        makespans[0],
        makespans[1],
        (1.0 - makespans[0] / makespans[1]) * 100.0
    );
    println!(
        "ImageGen step time under partitioning: {:.1}x greedy (paper ~1.8x)",
        img_norms[1] / img_norms[0]
    );
}
