//! Fig. 4: GPU utilization (SMACT vs SMOCC) of each application running
//! exclusively on the GPU, with the per-kernel occupancy analysis of §4.1.
//!
//! Paper shape: all three applications reserve nearly all SMs (SMACT ≈
//! 100% while active), but occupancy differs sharply — Chatbot's tuned
//! llama.cpp kernels run high SMOCC; ImageGen's 168-register attention
//! kernels cap at 1 block/SM; Whisper's decoder is worst (tiny kernels,
//! ~200 regs + heavy smem).

#[path = "common.rs"]
mod common;
use common::{header, monitor, run, util_row};

use consumerbench::apps::models::{llama_3_2_3b, sd35_medium_turbo, whisper_large_v3_turbo};
use consumerbench::gpusim::kernel::occupancy;
use consumerbench::gpusim::profiles::rtx6000;

fn main() {
    header("Fig. 4: GPU utilization, exclusive execution");
    for (label, app, n) in [
        ("Chatbot", "chatbot", 8usize),
        ("ImageGen", "imagegen", 6),
        ("LiveCaptions", "livecaptions", 30),
    ] {
        let cfg = format!("App ({app}):\n  num_requests: {n}\n  device: gpu\nseed: 42\n");
        let result = run(&cfg);
        let mon = monitor(&result);
        println!("\n  {label}:");
        util_row("SMACT", &mon.gpu_smact);
        util_row("SMOCC", &mon.gpu_smocc);
        println!(
            "  busy means: SMACT {:>5.1}%  SMOCC {:>5.1}%",
            mon.mean_busy_smact() * 100.0,
            mon.mean_busy_smocc() * 100.0
        );
    }

    header("§4.1 zoomed-in kernel analysis (registers → occupancy)");
    let gpu = rtx6000();
    let rows: Vec<(&str, consumerbench::gpusim::KernelDesc)> = vec![
        ("Chatbot decode (llama.cpp)", llama_3_2_3b().decode_kernels(512).remove(0)),
        ("ImageGen attention (PyTorch)", {
            let m = sd35_medium_turbo();
            m.denoise_step_kernels()
                .into_iter()
                .find(|k| k.tag == "denoise.attn")
                .unwrap()
        }),
        ("Whisper encoder matmul", whisper_large_v3_turbo().encode_kernels().remove(0)),
        ("Whisper decoder small", whisper_large_v3_turbo().decode_token_kernels().remove(0)),
    ];
    println!(
        "  {:<30} {:>6} {:>9} {:>10} {:>10} {:>14}",
        "kernel", "regs", "smem(KB)", "blocks/SM", "SMOCC", "limited by"
    );
    for (name, k) in rows {
        let occ = occupancy(&k, &gpu).unwrap();
        println!(
            "  {:<30} {:>6} {:>9.0} {:>10} {:>9.0}% {:>14}",
            name,
            k.regs_per_thread,
            k.smem_per_block as f64 / 1024.0,
            occ.blocks_per_sm,
            occ.occupancy * 100.0,
            format!("{}", occ.limiter),
        );
    }
    println!(
        "\npaper shape: SMACT ≈ 100% for all; SMOCC high for Chatbot, ~25-35%\n\
         for ImageGen (register pressure), <10% for Whisper's decoder."
    );
}
