//! Figs. 11-13 (appendix B.4): concurrent execution with a model that does
//! not fit — Chatbot upgraded to Llama-3.1-8B (16 GB fp16) runs on the CPU
//! while ImageGen and LiveCaptions share the GPU.
//!
//! Paper shape: the 8B Chatbot on CPU violates its SLOs; LiveCaptions still
//! sees violations under greedy but less starvation (only two apps contend
//! on the GPU); partitioning the GPU between ImageGen and LiveCaptions
//! removes the starvation entirely at a mild ImageGen cost.

#[path = "common.rs"]
mod common;
use common::{header, print_app_row, run};

fn config(strategy: &str) -> String {
    format!(
        "\
Chat-8B (chatbot):
  model: Llama-3.1-8B
  num_requests: 6
  device: cpu
  slo: [1s, 0.25s]
Image (imagegen):
  num_requests: 20
  device: gpu
  slo: 1s
Captions (livecaptions):
  num_requests: 60
  device: gpu
  slo: 2s
strategy: {strategy}
seed: 42
"
    )
}

fn main() {
    for strategy in ["greedy", "partition"] {
        header(&format!("Fig. 11: larger model (8B on CPU) — {strategy}"));
        let result = run(&config(strategy));
        for node in &result.nodes {
            print_app_row(&node.id, node);
        }
    }
    println!(
        "\npaper shape: 8B-on-CPU Chatbot misses SLOs on both rows; greedy\n\
         still degrades LiveCaptions (less than three-way contention);\n\
         partition eliminates LiveCaptions starvation, ImageGen slightly\n\
         slower than greedy."
    );
}
