//! Table 1: dataset, model, and SLO per application.

use consumerbench::apps::{Application, Chatbot, DeepResearch, ImageGen, LiveCaptions};

fn main() {
    println!("Table 1: Summary of dataset, model, and SLO used in each application");
    println!(
        "{:<14} {:<20} {:<28} {}",
        "Application", "Dataset", "Model", "SLO"
    );
    let apps: Vec<Box<dyn Application>> = vec![
        Box::new(Chatbot::new(0, 1)),
        Box::new(DeepResearch::new(0, 1)),
        Box::new(ImageGen::new(0, 1)),
        Box::new(LiveCaptions::new(0, 1)),
    ];
    for app in &apps {
        println!(
            "{:<14} {:<20} {:<28} {}",
            app.name(),
            app.dataset_name(),
            app.model_name(),
            app.slo().describe()
        );
    }
}
