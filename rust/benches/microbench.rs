//! Micro-benchmark suite → `BENCH.json`.
//!
//! The hot paths, each reported as a machine-readable entry so every
//! future PR has a perf trajectory to regress against:
//!
//! * **engine-throughput** — simulated kernel-events per second through the
//!   discrete-event engine, with trace recording on and off, plus a
//!   per-queue-backend pair (`_heap` / `_wheel`, both untraced so the
//!   event-queue cost dominates) and a streaming-trace-mode run;
//! * **job-slab** — job submissions per second through the slab allocator
//!   (the `submit` hot path: slab insert + queue push);
//! * **sweep-wall-clock** — scenario-matrix wall time at `--jobs 1` vs.
//!   all available workers (the parallel-sweep speedup);
//! * **digest-rate** — bytes per second through the streaming FNV-1a trace
//!   digest;
//! * **server-throughput** — unified-batch iterations per second through
//!   the inference server's hot path, static vs. under adaptive
//!   reconfiguration churn (slot/batch resizes every 32 iterations);
//! * **kernel-trace-gen** — per-backend kernel-trace generation throughput
//!   (llama decode + prefill, SD denoise step, whisper token) — the
//!   per-request synthesis path every scenario pays, per kernel backend;
//! * **fleet-aggregation** — device-record folds per second into a fleet
//!   aggregate (histograms + moments + tier table + outlier selection: the
//!   per-device cost of the bounded-memory fleet sweep) and fixed-bin
//!   histogram merges per second (the per-shard cost of the final fold).
//!
//! Usage (a `harness = false` bench target):
//!
//! ```text
//! cargo bench --bench microbench [-- --fast] [-- --out PATH]
//! ```
//!
//! `--fast` shrinks the workloads for CI smoke runs; `--out` overrides the
//! default output path. Only a full run defaults to the committed
//! `BENCH.json` at the repository root — fast mode defaults to
//! `target/BENCH-fast.json` so a smoke run can't silently overwrite the
//! perf-trajectory baseline with non-comparable numbers.

use std::time::Instant;

use consumerbench::apps::models::{llama_3_2_3b, sd35_medium_turbo, whisper_large_v3_turbo};
use consumerbench::gpusim::backend::KernelBackend;
use consumerbench::gpusim::engine::{
    trace_digest, Engine, EngineOptions, JobSpec, Phase, QueueBackend, Trace, TraceMode,
};
use consumerbench::gpusim::policy::Policy;
use consumerbench::gpusim::profiles::Testbed;
use consumerbench::scenario::{
    run_matrix_jobs, DeviceClass, DeviceRecord, FleetAggregate, MatrixAxes, ScenarioStatus,
};
use consumerbench::server::{InferenceServer, ServerConfig, ServerRequest, ServerTuning};
use consumerbench::util::json::{json_num, json_str};
use consumerbench::util::stats::FixedHistogram;

#[path = "common.rs"]
mod common;
use common::engine_events_per_sec;

struct Entry {
    name: &'static str,
    value: f64,
    unit: &'static str,
}

/// Kernel-trace generations per second for one backend: each iteration
/// synthesizes a llama decode token (long context), a llama prefill, an SD
/// denoise step, and a whisper decode token — the per-request work the
/// executor pays before the engine ever sees a kernel.
fn kernel_trace_gens_per_sec(backend: KernelBackend, reps: usize) -> f64 {
    let llama = llama_3_2_3b().with_backend(backend);
    let sd = sd35_medium_turbo().with_backend(backend);
    let whisper = whisper_large_v3_turbo().with_backend(backend);
    let t0 = Instant::now();
    let mut kernels = 0usize;
    for i in 0..reps.max(1) {
        let ctx = 4096 + (i % 16) * 64;
        kernels += std::hint::black_box(llama.decode_kernels(ctx)).len();
        kernels += std::hint::black_box(llama.prefill_kernels(512)).len();
        kernels += std::hint::black_box(sd.denoise_step_kernels()).len();
        kernels += std::hint::black_box(whisper.decode_token_kernels()).len();
    }
    let dt = t0.elapsed().as_secs_f64();
    std::hint::black_box(kernels);
    (reps.max(1) * 4) as f64 / dt.max(1e-9)
}

/// Job submissions per second through the engine's slab allocator: the
/// `submit` hot path is a slab insert plus an event-queue push. The jobs
/// are tiny host phases so the subsequent `run_all` (correctness check
/// only) stays cheap.
fn job_slab_submit_per_sec(jobs: usize) -> f64 {
    let mut e = Engine::with_options(
        Testbed::intel_server(),
        Policy::Greedy,
        EngineOptions {
            capacity_hint: jobs,
            ..Default::default()
        },
    );
    e.set_trace_enabled(false);
    let c = e.register_client("slab");
    let t0 = Instant::now();
    for j in 0..jobs {
        e.submit(
            JobSpec {
                client: c,
                label: String::new(),
                phases: vec![Phase::host("h", 1e-6)],
            },
            j as f64 * 1e-6,
        );
    }
    let dt = t0.elapsed().as_secs_f64();
    e.run_all();
    assert_eq!(e.take_completed().len(), jobs, "bench must complete all jobs");
    jobs as f64 / dt.max(1e-9)
}

/// Streaming digest throughput over a recorded engine trace.
fn digest_bytes_per_sec(trace: &Trace, reps: usize) -> f64 {
    // Canonical size: per row 44 bytes of scalar counters (t f64 + 7×f32 +
    // vram u64) + an 8-byte per-client count + 8 bytes per client entry,
    // then the 8-byte trace-length suffix.
    let per_client_bytes: usize = (0..trace.len()).map(|i| trace.per_client(i).len() * 8).sum();
    let bytes = 8 + trace.len() * 52 + per_client_bytes;
    let t0 = Instant::now();
    let mut acc = 0u64;
    for _ in 0..reps.max(1) {
        acc = acc.wrapping_add(std::hint::black_box(trace_digest(trace)));
    }
    let dt = t0.elapsed().as_secs_f64();
    std::hint::black_box(acc);
    (bytes * reps.max(1)) as f64 / dt.max(1e-9)
}

/// Unified-batch iterations per second through the serving hot path. With
/// `adaptive`, the tuning is flipped (slots 4↔2, batch 512↔256) every 32
/// iterations, so the number includes drain + reconfiguration overhead —
/// the cost the adaptive controller pays for each action.
fn server_batches_per_sec(adaptive: bool, n_requests: usize) -> f64 {
    let mut e = Engine::new(Testbed::intel_server(), Policy::Greedy);
    e.set_trace_enabled(false);
    let c = e.register_client("llama-server");
    let mut s = InferenceServer::new(ServerConfig::kv_gpu(llama_3_2_3b()), c);
    s.start(&mut e, 0.0);
    e.run_all();
    e.take_completed();
    for i in 0..n_requests {
        s.enqueue(
            ServerRequest {
                id: i as u64,
                app: "Chatbot",
                prompt_tokens: 128 + (i % 7) * 64,
                output_tokens: 48,
            },
            0.0,
        );
    }
    let t0 = Instant::now();
    let mut last_flip = 0u64;
    let mut shrunk = false;
    loop {
        s.pump(&mut e, e.now());
        let Some(t) = e.next_event_time() else { break };
        e.run_until(t);
        for r in e.take_completed() {
            s.on_job_done(&r);
        }
        if adaptive && s.iterations() >= last_flip + 32 {
            last_flip = s.iterations();
            shrunk = !shrunk;
            let (n_slots, batch_size) = if shrunk { (2, 256) } else { (4, 512) };
            s.reconfigure(
                &mut e,
                e.now(),
                ServerTuning {
                    n_slots,
                    batch_size,
                    ..s.tuning()
                },
            );
        }
        if s.idle() && e.next_event_time().is_none() {
            break;
        }
    }
    let iters = s.iterations();
    assert_eq!(s.take_responses().len(), n_requests, "bench must serve all");
    iters as f64 / t0.elapsed().as_secs_f64().max(1e-9)
}

/// Device-record folds per second into one fleet aggregate: fixed-bin
/// histogram folds + streaming moment pushes + tier-table upsert + bounded
/// worst-k outlier selection — the entire per-device cost of the
/// bounded-memory fleet sweep (everything except running the scenario).
fn fleet_agg_folds_per_sec(records: usize) -> f64 {
    let classes = [DeviceClass::Edge, DeviceClass::Laptop, DeviceClass::Desktop];
    let vram = [4u64, 16, 24];
    let recs: Vec<DeviceRecord> = (0..records.max(1))
        .map(|i| DeviceRecord {
            device: i,
            class: classes[i % 3],
            vram_gb: vram[i % 3],
            status: ScenarioStatus::Ok,
            error: None,
            retried: false,
            attainment: Some((i % 100) as f64 / 100.0),
            makespan: 1.0 + (i % 7) as f64,
            e2e_latency: 0.9 + (i % 7) as f64,
            trace_digest: i as u64,
            trace_rows: 128,
            latencies: vec![0.05 + (i % 50) as f64 * 0.01; 8],
        })
        .collect();
    let mut agg = FleetAggregate::new(8, 128);
    let t0 = Instant::now();
    for rec in &recs {
        agg.fold(std::hint::black_box(rec), None);
    }
    let dt = t0.elapsed().as_secs_f64();
    assert_eq!(agg.device_count(), records.max(1), "bench must fold all records");
    std::hint::black_box(agg.cells());
    records.max(1) as f64 / dt.max(1e-9)
}

/// Fixed-bin histogram merges per second (the fleet latency layout:
/// log-scale 1e-4..1e4 s, 96 bins) — the per-shard cost of the final fold.
fn histogram_merges_per_sec(reps: usize) -> f64 {
    let mut base = FixedHistogram::log_scale(1e-4, 1e4, 96);
    let mut other = FixedHistogram::log_scale(1e-4, 1e4, 96);
    for i in 0..4096 {
        other.fold(1e-3 * (1.0 + (i % 977) as f64));
    }
    let t0 = Instant::now();
    for _ in 0..reps.max(1) {
        base.merge(std::hint::black_box(&other));
    }
    let dt = t0.elapsed().as_secs_f64();
    std::hint::black_box(base.count());
    reps.max(1) as f64 / dt.max(1e-9)
}

/// Scenario-matrix sweep wall-clock at a given worker count.
fn sweep_wall_clock(axes: &MatrixAxes, jobs: usize) -> f64 {
    let t0 = Instant::now();
    let report = run_matrix_jobs(axes, jobs).expect("sweep failed");
    let dt = t0.elapsed().as_secs_f64();
    assert!(!report.scenarios.is_empty());
    dt
}

fn render_json(mode: &str, jobs: usize, entries: &[Entry]) -> String {
    let mut out = String::with_capacity(1024);
    out.push_str("{\n");
    out.push_str("  \"consumerbench_bench\": 1,\n");
    out.push_str(&format!("  \"mode\": {},\n", json_str(mode)));
    out.push_str(&format!("  \"sweep_jobs\": {jobs},\n"));
    out.push_str("  \"entries\": [\n");
    for (i, e) in entries.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"name\": {}, \"value\": {}, \"unit\": {}}}",
            json_str(e.name),
            json_num(e.value),
            json_str(e.unit)
        ));
        out.push_str(if i + 1 < entries.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ]\n");
    out.push_str("}\n");
    out
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let fast = args.iter().any(|a| a == "--fast");
    let out_path = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| {
            if fast {
                // Don't clobber the committed full-mode baseline with
                // non-comparable smoke numbers.
                format!("{}/target/BENCH-fast.json", env!("CARGO_MANIFEST_DIR"))
            } else {
                format!("{}/../BENCH.json", env!("CARGO_MANIFEST_DIR"))
            }
        });

    let (jobs, kernels, digest_reps, server_reqs, gen_reps, slab_jobs) = if fast {
        (200, 25, 20, 64, 500, 20_000)
    } else {
        (2_000, 50, 100, 512, 5_000, 200_000)
    };
    let mode = if fast { "fast" } else { "full" };

    let (eps_traced, trace) =
        engine_events_per_sec(QueueBackend::Heap, Some(TraceMode::Full), jobs, kernels);
    let (eps_untraced, _) = engine_events_per_sec(QueueBackend::Heap, None, jobs, kernels);
    // Per-queue-backend pair, both untraced so the queue cost dominates.
    let (eps_heap, _) = engine_events_per_sec(QueueBackend::Heap, None, jobs, kernels);
    let (eps_wheel, _) = engine_events_per_sec(QueueBackend::Wheel, None, jobs, kernels);
    let (eps_streaming, _) = engine_events_per_sec(
        QueueBackend::Heap,
        Some(TraceMode::Streaming { window: 512 }),
        jobs,
        kernels,
    );
    let slab_rate = job_slab_submit_per_sec(slab_jobs);
    let digest_rate = digest_bytes_per_sec(&trace, digest_reps);
    let server_static = server_batches_per_sec(false, server_reqs);
    let server_adaptive = server_batches_per_sec(true, server_reqs);
    let gen_tuned = kernel_trace_gens_per_sec(KernelBackend::TunedNative, gen_reps);
    let gen_generic = kernel_trace_gens_per_sec(KernelBackend::GenericTorch, gen_reps);
    let gen_fused = kernel_trace_gens_per_sec(KernelBackend::FusedCustom, gen_reps);
    let (fold_records, merge_reps) = if fast { (2_000, 10_000) } else { (20_000, 100_000) };
    let fleet_fold = fleet_agg_folds_per_sec(fold_records);
    let hist_merge = histogram_merges_per_sec(merge_reps);

    // detlint: pin(default-matrix-count: 68)
    let mut axes = MatrixAxes::default_matrix(42);
    if fast {
        axes.mixes.truncate(1); // static + adaptive chat only …
        axes.workflows.clear(); // … no workflow slice …
        axes.backends.clear(); // … no backend-ablation slice …
        axes.chaos.clear(); // … no chaos slice: 12 scenarios, not 68
    }
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let sweep_seq = sweep_wall_clock(&axes, 1);
    let sweep_par = sweep_wall_clock(&axes, workers);

    let entries = [
        Entry {
            name: "engine_events_per_sec_traced",
            value: eps_traced,
            unit: "events/s",
        },
        Entry {
            name: "engine_events_per_sec_untraced",
            value: eps_untraced,
            unit: "events/s",
        },
        Entry {
            name: "engine_events_per_sec_heap",
            value: eps_heap,
            unit: "events/s",
        },
        Entry {
            name: "engine_events_per_sec_wheel",
            value: eps_wheel,
            unit: "events/s",
        },
        Entry {
            name: "streaming_trace_events_per_sec",
            value: eps_streaming,
            unit: "events/s",
        },
        Entry {
            name: "job_slab_submit_per_sec",
            value: slab_rate,
            unit: "jobs/s",
        },
        Entry {
            name: "trace_digest_rate",
            value: digest_rate,
            unit: "bytes/s",
        },
        Entry {
            name: "server_batches_per_sec_static",
            value: server_static,
            unit: "batches/s",
        },
        Entry {
            name: "server_batches_per_sec_adaptive",
            value: server_adaptive,
            unit: "batches/s",
        },
        Entry {
            name: "kernel_trace_gen_tuned_native",
            value: gen_tuned,
            unit: "traces/s",
        },
        Entry {
            name: "kernel_trace_gen_generic_torch",
            value: gen_generic,
            unit: "traces/s",
        },
        Entry {
            name: "kernel_trace_gen_fused_custom",
            value: gen_fused,
            unit: "traces/s",
        },
        Entry {
            name: "fleet_agg_fold_per_sec",
            value: fleet_fold,
            unit: "records/s",
        },
        Entry {
            name: "histogram_merge_per_sec",
            value: hist_merge,
            unit: "merges/s",
        },
        Entry {
            name: "sweep_wall_clock_jobs1",
            value: sweep_seq,
            unit: "s",
        },
        Entry {
            name: "sweep_wall_clock_jobsN",
            value: sweep_par,
            unit: "s",
        },
        Entry {
            name: "sweep_parallel_speedup",
            value: sweep_seq / sweep_par.max(1e-9),
            unit: "x",
        },
    ];

    let json = render_json(mode, workers, &entries);
    if let Some(parent) = std::path::Path::new(&out_path).parent() {
        let _ = std::fs::create_dir_all(parent);
    }
    std::fs::write(&out_path, &json).expect("writing BENCH.json");

    println!("=== ConsumerBench micro-benchmarks ({mode}) ===");
    for e in &entries {
        println!("{:<34} {:>14.1} {}", e.name, e.value, e.unit);
    }
    println!("wrote {out_path}");
}
