//! Figs. 18-22 (appendix C): the Apple Silicon (MacBook M1 Pro) testbed —
//! exclusive vs concurrent execution, model sharing, and the content-
//! creation workflow under the unified-memory fair-share scheduler.
//!
//! Paper shape: exclusive runs meet their (relaxed, 4 s LiveCaptions) SLOs;
//! concurrent execution degrades ImageGen slightly and LiveCaptions
//! substantially (~8x vs 9.5x on the Intel server — fairer but still
//! suboptimal); Chatbot-KVCache-CPU behaves like on the Intel box; power
//! is an order of magnitude below the discrete-GPU server.

#[path = "common.rs"]
mod common;
use common::{header, monitor, print_app_row, run};

fn exclusive(app: &str, n: usize) -> String {
    format!(
        "App ({app}):\n  num_requests: {n}\n  device: gpu\ntestbed: macbook_m1_pro\nstrategy: fair_share\nseed: 42\n"
    )
}

fn concurrent() -> String {
    "\
Chat (chatbot):
  num_requests: 8
  device: gpu
Image (imagegen):
  num_requests: 15
  device: gpu
Captions (livecaptions):
  num_requests: 40
  device: gpu
testbed: macbook_m1_pro
strategy: fair_share
seed: 42
"
    .to_string()
}

fn main() {
    header("Fig. 18/19: exclusive on Apple Silicon (fair-share scheduler)");
    let mut lc_excl = 0.0;
    for (label, app, n) in [
        ("Chatbot", "chatbot", 8usize),
        ("ImageGen", "imagegen", 6),
        ("LiveCaptions", "livecaptions", 30),
    ] {
        let result = run(&exclusive(app, n));
        let node = &result.nodes[0];
        print_app_row(label, node);
        if label == "LiveCaptions" {
            lc_excl = node.metrics.iter().map(|m| m.latency).sum::<f64>()
                / node.metrics.len() as f64;
        }
        let mon = monitor(&result);
        println!(
            "    GPU power: mean-busy {:.1} W, peak {:.1} W (laptop-class)",
            mon.gpu_power
                .values()
                .iter()
                .copied()
                .filter(|&v| v > 5.0)
                .sum::<f64>()
                / mon.gpu_power.values().iter().filter(|&&v| v > 5.0).count().max(1) as f64,
            mon.gpu_power.max()
        );
    }

    header("Fig. 18 (right): concurrent on Apple Silicon");
    let result = run(&concurrent());
    for node in &result.nodes {
        print_app_row(&node.id, node);
    }
    let lc = result.node("Captions (livecaptions)").unwrap();
    let lc_conc =
        lc.metrics.iter().map(|m| m.latency).sum::<f64>() / lc.metrics.len() as f64;
    println!(
        "  LiveCaptions degradation: {:.1}x exclusive (paper: ~8x vs 9.5x on Intel)",
        lc_conc / lc_excl
    );

    header("Fig. 20/21: model sharing on Apple Silicon");
    for (label, kv) in [("KV on GPU (unified)", "gpu"), ("Chatbot-KVCache-CPU", "cpu")] {
        let cfg = format!(
            "\
Chat (chatbot):
  num_requests: 8
  device: gpu
  server: llama
  slo: [1s, 0.25s]
Research (deepresearch):
  num_requests: 1
  device: gpu
  server: llama
servers:
  llama:
    model: Llama-3.2-3B
    context_window: {}
    kv_placement: {kv}
testbed: macbook_m1_pro
strategy: fair_share
seed: 42
",
            if kv == "gpu" { 16_384 } else { 131_072 }
        );
        let result = run(&cfg);
        let chat = result.node("Chat (chatbot)").unwrap();
        println!(
            "  {:<24} chat SLO attainment {}",
            label,
            consumerbench::apps::attainment_pct(chat.attainment())
        );
    }
    println!(
        "\npaper shape: fair-share improves the balance vs greedy-Intel but\n\
         LiveCaptions still degrades; KV-on-CPU hurts chat the same way;\n\
         all at laptop-class power."
    );
}
