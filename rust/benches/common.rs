//! Shared helpers for the figure-regeneration benches.
//!
//! Every bench is a `harness = false` binary that runs a scenario on the
//! simulated testbed and prints the same rows/series the paper's figure
//! plots. Absolute numbers come from the simulator calibration; the claims
//! under test are the *shapes*: who wins, by roughly what factor, where the
//! crossovers fall (DESIGN.md §6).

use consumerbench::coordinator::{run_config_text, NodeResult, ScenarioResult};
use consumerbench::monitor::MonitorReport;

/// Run a config without PJRT (virtual-time measurement only — artifacts are
/// exercised by `make test` and the examples).
pub fn run(cfg: &str) -> ScenarioResult {
    run_config_text(cfg, None).unwrap_or_else(|e| panic!("scenario failed: {e}"))
}

/// Monitor view of a result.
pub fn monitor(result: &ScenarioResult) -> MonitorReport {
    MonitorReport::from_trace(&result.trace, &result.client_names, 0.1)
}

/// Print the standard per-application row (Fig. 3/5-style).
pub fn print_app_row(label: &str, node: &NodeResult) {
    println!(
        "  {:<26} norm-latency {:>7.2}x   SLO attainment {:>5.1}%   ({} reqs)",
        label,
        node.mean_normalized(),
        node.attainment() * 100.0,
        node.metrics.len()
    );
}

/// Mean of a named metric component across a node's requests.
pub fn mean_component(node: &NodeResult, name: &str) -> f64 {
    let vals: Vec<f64> = node
        .metrics
        .iter()
        .filter_map(|m| m.components.iter().find(|(n, _)| *n == name).map(|(_, v)| *v))
        .collect();
    if vals.is_empty() {
        0.0
    } else {
        vals.iter().sum::<f64>() / vals.len() as f64
    }
}

pub fn header(title: &str) {
    println!("\n=== {title} ===");
}

/// Render a utilization sparkline row.
pub fn util_row(name: &str, series: &consumerbench::util::TimeSeries) {
    println!("  {:<10} {}  (mean {:.0}%)", name, series.sparkline(48, 1.0), series.mean() * 100.0);
}
