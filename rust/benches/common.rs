//! Shared helpers for the figure-regeneration benches.
//!
//! Every bench is a `harness = false` binary that runs a scenario on the
//! simulated testbed and prints the same rows/series the paper's figure
//! plots. Absolute numbers come from the simulator calibration; the claims
//! under test are the *shapes*: who wins, by roughly what factor, where the
//! crossovers fall (DESIGN.md §6).

use std::time::Instant;

use consumerbench::coordinator::{run_config_text, NodeResult, ScenarioResult};
use consumerbench::gpusim::engine::{
    Engine, EngineOptions, JobSpec, Phase, QueueBackend, Trace, TraceMode,
};
use consumerbench::gpusim::kernel::KernelDesc;
use consumerbench::gpusim::policy::Policy;
use consumerbench::gpusim::profiles::Testbed;
use consumerbench::monitor::MonitorReport;

/// Run a config without PJRT (virtual-time measurement only — artifacts are
/// exercised by `make test` and the examples).
pub fn run(cfg: &str) -> ScenarioResult {
    run_config_text(cfg, None).unwrap_or_else(|e| panic!("scenario failed: {e}"))
}

/// Monitor view of a result (same grid as the coordinator's reports).
pub fn monitor(result: &ScenarioResult) -> MonitorReport {
    MonitorReport::from_trace(
        &result.trace,
        &result.client_names,
        consumerbench::monitor::DEFAULT_INTERVAL,
        result.gpu_idle_w,
        result.cpu_idle_w,
    )
}

/// Shared engine-throughput workload (perf_engine + microbench): `jobs`
/// jobs × `kernels_per_job` kernels with interleaved arrivals across four
/// clients under Greedy, on the given queue backend. `trace` is the
/// recording mode (`None` disables tracing entirely). Returns
/// (kernel-events per second, the recorded trace — the tail window under
/// streaming). One definition so the bench targets stay comparable.
#[allow(dead_code)]
pub fn engine_events_per_sec(
    queue: QueueBackend,
    trace: Option<TraceMode>,
    jobs: usize,
    kernels_per_job: usize,
) -> (f64, Trace) {
    let mut e = Engine::with_options(
        Testbed::intel_server(),
        Policy::Greedy,
        EngineOptions {
            queue,
            trace_mode: trace.unwrap_or_default(),
            capacity_hint: jobs,
        },
    );
    e.set_trace_enabled(trace.is_some());
    let clients: Vec<_> = (0..4).map(|i| e.register_client(format!("c{i}"))).collect();
    let kernel = KernelDesc::new("k", 288, 256, 80, 8 * 1024, 1e8, 5e6);
    for j in 0..jobs {
        e.submit(
            JobSpec {
                client: clients[j % clients.len()],
                label: format!("j{j}"),
                phases: vec![Phase::gpu("p", 0.0, vec![kernel.clone(); kernels_per_job])],
            },
            j as f64 * 1e-4,
        );
    }
    let events = (jobs * kernels_per_job * 2) as f64; // launch + completion
    let t0 = Instant::now();
    e.run_all();
    let dt = t0.elapsed().as_secs_f64();
    assert_eq!(e.take_completed().len(), jobs);
    (events / dt.max(1e-9), e.take_trace())
}

/// Print the standard per-application row (Fig. 3/5-style).
pub fn print_app_row(label: &str, node: &NodeResult) {
    println!(
        "  {:<26} norm-latency {:>7.2}x   SLO attainment {}   ({} reqs)",
        label,
        node.mean_normalized(),
        consumerbench::apps::attainment_pct(node.attainment()),
        node.metrics.len()
    );
}

/// Mean of a named metric component across a node's requests.
pub fn mean_component(node: &NodeResult, name: &str) -> f64 {
    let vals: Vec<f64> = node
        .metrics
        .iter()
        .filter_map(|m| m.components.iter().find(|(n, _)| *n == name).map(|(_, v)| *v))
        .collect();
    if vals.is_empty() {
        0.0
    } else {
        vals.iter().sum::<f64>() / vals.len() as f64
    }
}

pub fn header(title: &str) {
    println!("\n=== {title} ===");
}

/// Render a utilization sparkline row.
pub fn util_row(name: &str, series: &consumerbench::util::TimeSeries) {
    println!("  {:<10} {}  (mean {:.0}%)", name, series.sparkline(48, 1.0), series.mean() * 100.0);
}
