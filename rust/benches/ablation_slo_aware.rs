//! Ablation: the paper's §5.2 "SLO-Aware Scheduling" insight, implemented
//! and measured against the three evaluated strategies on the Fig. 5
//! workload.
//!
//! Hypothesis (paper §5.2): prioritizing latency-sensitive clients with a
//! small SM reservation should protect LiveCaptions like partitioning does
//! — **without** partitioning's throughput collapse for ImageGen or the
//! Fig. 7 workflow-makespan penalty.

#[path = "common.rs"]
mod common;
use common::{header, print_app_row, run};

fn config(strategy: &str) -> String {
    format!(
        "\
Chat (chatbot):
  num_requests: 10
  device: gpu
  slo: [1s, 0.25s]
Image (imagegen):
  num_requests: 25
  device: gpu
  slo: 1s
Captions (livecaptions):
  num_requests: 75
  device: gpu
  slo: 2s
strategy: {strategy}
seed: 42
"
    )
}

fn main() {
    println!("Ablation: resource-orchestration strategies on the Fig. 5 workload");
    let mut rows = Vec::new();
    for strategy in ["greedy", "partition", "fair_share", "slo_aware"] {
        header(strategy);
        let result = run(&config(strategy));
        for node in &result.nodes {
            print_app_row(&node.id, node);
        }
        println!("  makespan: {:.1} s", result.makespan);
        let lc_node = result.node("Captions (livecaptions)").unwrap();
        let lc = lc_node.attainment().expect("requests ran");
        let ig = result.node("Image (imagegen)").unwrap();
        rows.push((strategy, lc, ig.mean_normalized(), result.makespan));
    }
    println!("\n--- summary (LiveCaptions attainment / ImageGen step x / makespan) ---");
    for (s, lc, ig, mk) in rows {
        println!("  {s:<11} {:>5.1}% {:>8.2}x {:>8.1}s", lc * 100.0, ig, mk);
    }
    println!(
        "\nexpected: slo_aware matches partition's LiveCaptions protection\n\
         while keeping ImageGen near its greedy/exclusive step time — the\n\
         dynamic, SLO-aware middle ground the paper calls for."
    );
}
