//! Fig. 3: latencies normalized to SLO (a) and SLO attainment (b) for
//! Chatbot, ImageGen, and LiveCaptions running exclusively on the GPU
//! (upper bound) or the CPU (lower bound).
//!
//! Paper shape: on the GPU everything meets its SLO (LiveCaptions loses
//! 3/150 segments to language-ID re-encodes); on the CPU Chatbot narrowly
//! misses while ImageGen and LiveCaptions blow far past their budgets.

#[path = "common.rs"]
mod common;
use common::{header, print_app_row, run};

fn scenario(app: &str, device: &str, n: usize) -> String {
    let slo = match app {
        "chatbot" => "  slo: [1s, 0.25s]\n",
        "imagegen" => "  slo: 1s\n",
        _ => "  slo: 2s\n",
    };
    format!(
        "App ({app}):\n  num_requests: {n}\n  device: {device}\n{slo}strategy: greedy\nseed: 42\n"
    )
}

fn main() {
    // Request counts follow the paper: 150 audio segments; CPU runs use
    // fewer requests for the slow apps (the paper's CPU numbers are also
    // from shorter runs — latencies per request are what is plotted).
    let cases = [
        ("Chatbot", "chatbot", 10usize, 6usize),
        ("ImageGen", "imagegen", 10, 3),
        ("LiveCaptions", "livecaptions", 150, 10),
    ];
    header("Fig. 3(a,b): exclusive GPU (upper bound) vs CPU (lower bound)");
    for (label, app, n_gpu, n_cpu) in cases {
        for (device, n) in [("gpu", n_gpu), ("cpu", n_cpu)] {
            let result = run(&scenario(app, device, n));
            let node = &result.nodes[0];
            print_app_row(&format!("{label} [{device}]"), node);
        }
    }
    println!(
        "\npaper shape: GPU rows ~100% attainment (LiveCaptions ≈ 98% from\n\
         re-encoded segments); CPU rows: Chatbot ≈ 1-2x (narrow miss),\n\
         ImageGen and LiveCaptions one-to-two orders over budget."
    );
}
