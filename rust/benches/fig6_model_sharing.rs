//! Fig. 6: static model sharing via an inference server (§4.2.1).
//!
//! Chatbot (latency-sensitive) and DeepResearch (background, 128K context)
//! share one Llama-3.2-3B llama.cpp server. The DeepResearch-friendly
//! configuration provisions a 16 GB-class KV cache in CPU DRAM
//! (`--no-kv-offload`), pulling every attention op onto the CPU.
//!
//! Paper shape: Chatbot-KVCache-CPU misses its SLO for ~40% of requests
//! with high variance; CPU utilization is high while GPU utilization drops.

#[path = "common.rs"]
mod common;
use common::{header, mean_component, monitor, run};

fn config(kv: &str, ctx: usize) -> String {
    format!(
        "\
Chat (chatbot):
  num_requests: 25
  device: gpu
  server: llama
  slo: [1s, 0.25s]
Research (deepresearch):
  num_requests: 2
  device: gpu
  server: llama
servers:
  llama:
    model: Llama-3.2-3B
    context_window: {ctx}
    kv_placement: {kv}
strategy: greedy
seed: 42
"
    )
}

fn main() {
    for (label, kv, ctx) in [
        ("Chatbot (KV on GPU, 4K ctx)", "gpu", 4096usize),
        ("Chatbot-KVCache-CPU (128K ctx)", "cpu", 131_072),
    ] {
        header(&format!("Fig. 6: {label}"));
        let result = run(&config(kv, ctx));
        let chat = result.node("Chat (chatbot)").unwrap();
        let ttfts: Vec<f64> = chat
            .metrics
            .iter()
            .filter_map(|m| m.components.iter().find(|(n, _)| *n == "ttft").map(|(_, v)| *v))
            .collect();
        let var = {
            let mean = ttfts.iter().sum::<f64>() / ttfts.len() as f64;
            (ttfts.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / ttfts.len() as f64).sqrt()
                / mean
        };
        println!(
            "  chat: SLO attainment {}  mean TTFT {:.2}s (cv {:.2})  mean TPOT {:.3}s",
            consumerbench::apps::attainment_pct(chat.attainment()),
            mean_component(chat, "ttft"),
            var,
            mean_component(chat, "tpot"),
        );
        let mon = monitor(&result);
        println!(
            "  util: GPU SMACT(busy) {:>5.1}%   CPU(busy) {:>5.1}%   GPU energy {:.0} J   CPU energy {:.0} J",
            mon.mean_busy_smact() * 100.0,
            mon.cpu_util
                .values()
                .iter()
                .copied()
                .filter(|&v| v > 1e-6)
                .sum::<f64>()
                / mon.cpu_util.values().iter().filter(|&&v| v > 1e-6).count().max(1) as f64
                * 100.0,
            mon.gpu_energy(),
            mon.cpu_energy(),
        );
        let dr = result.node("Research (deepresearch)").unwrap();
        println!(
            "  research task: {:.1}s   makespan {:.1}s",
            dr.metrics.first().map(|m| m.latency).unwrap_or(0.0),
            result.makespan
        );
    }
    println!(
        "\npaper shape: KV-on-GPU serves chat within SLO; KV-on-CPU misses\n\
         ~40% of chat SLOs with high variance, high CPU util, low GPU util."
    );
}
