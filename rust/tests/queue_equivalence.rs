//! Queue-backend and trace-mode equivalence suite (ISSUE 8).
//!
//! The determinism contract after the engine-speed campaign: the timer
//! wheel is byte-identical to the binary heap, and streaming trace
//! recording digests to the same golden fingerprint as a fully
//! materialized trace — at the queue level (pop sequences), the engine
//! level (digests, budget stopping points), and the scenario level (the
//! matrix report's `trace_digest` column across `--jobs 1/4`, including
//! the chaos and workflow slices). Plus the bounded-allocation proof that
//! streaming peak trace memory is O(window), independent of run length.

use consumerbench::gpusim::engine::{
    BudgetExhausted, Engine, EngineError, EngineOptions, JobId, JobSpec, Phase, QueueBackend,
    TraceMode,
};
use consumerbench::gpusim::kernel::KernelDesc;
use consumerbench::gpusim::policy::Policy;
use consumerbench::gpusim::profiles::Testbed;
use consumerbench::gpusim::queue::{Event, EventKind, EventQueue, HeapQueue, TimerWheelQueue};
use consumerbench::gpusim::trace::trace_digest;
use consumerbench::scenario::{run_specs_jobs, MatrixAxes, ScenarioSpec};

/// Deterministic LCG (no external rand crate): same stream every run.
struct Lcg(u64);

impl Lcg {
    fn next(&mut self) -> u64 {
        self.0 = self
            .0
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        self.0 >> 33
    }
}

fn ev(time: f64, seq: u64) -> Event {
    let kind = match seq % 3 {
        0 => EventKind::PhaseBegin,
        1 => EventKind::KernelDone,
        _ => EventKind::CpuDone,
    };
    Event {
        time,
        seq,
        kind,
        job: JobId(seq),
    }
}

// ---------------------------------------------------------------------
// Queue level: the pop sequence is a pure function of the push sequence,
// identical across backends.
// ---------------------------------------------------------------------

/// Randomized schedules over several seeds: interleaved push/pop with
/// heavy same-timestamp ties, sub-tick deltas, cross-level spreads, and
/// beyond-horizon jumps that exercise the wheel's overflow list. The heap
/// is the reference; the wheel must reproduce its pop order exactly —
/// `(time-bits, seq, kind, job)` per event.
#[test]
fn randomized_schedules_pop_identically_on_both_backends() {
    for seed in [1u64, 0xdead_beef, 0x2545_f491_4f6c_dd1d, 98765] {
        let mut rng = Lcg(seed);
        let mut heap = HeapQueue::with_capacity(32);
        let mut wheel = TimerWheelQueue::with_capacity(32);
        let mut seq = 0u64;
        let mut now = 0.0f64;
        for step in 0..5_000 {
            if rng.next() % 3 == 0 {
                let a = heap.pop();
                let b = wheel.pop();
                match (a, b) {
                    (None, None) => {}
                    (Some(x), Some(y)) => {
                        assert_eq!(x.time.to_bits(), y.time.to_bits(), "seed {seed} step {step}");
                        assert_eq!(x.seq, y.seq, "seed {seed} step {step}");
                        assert_eq!(x.kind, y.kind);
                        assert_eq!(x.job, y.job);
                        now = x.time;
                    }
                    other => panic!("seed {seed} step {step}: pop mismatch {other:?}"),
                }
            } else {
                // Non-decreasing relative to the last pop — the engine's
                // usage pattern (events are never scheduled in the past).
                let dt = match rng.next() % 6 {
                    0 => 0.0,                                    // exact tie
                    1 => (rng.next() % 90) as f64 * 1e-9,        // sub-tick
                    2 => (rng.next() % 1_000) as f64 * 1e-7,     // level 0/1
                    3 => (rng.next() % 1_000) as f64 * 1e-3,     // mid levels
                    4 => (rng.next() % 50) as f64 * 1e3,         // high levels
                    _ => 3.0e7 + (rng.next() % 8) as f64 * 1e7,  // overflow
                };
                let e = ev(now + dt, seq);
                seq += 1;
                heap.push(e);
                wheel.push(e);
            }
            assert_eq!(heap.len(), wheel.len(), "seed {seed} step {step}");
            assert_eq!(
                heap.peek_time().map(f64::to_bits),
                wheel.peek_time().map(f64::to_bits),
                "seed {seed} step {step}"
            );
        }
        // Drain the remainder in lockstep.
        loop {
            match (heap.pop(), wheel.pop()) {
                (None, None) => break,
                (Some(x), Some(y)) => {
                    assert_eq!((x.time.to_bits(), x.seq), (y.time.to_bits(), y.seq));
                }
                other => panic!("seed {seed} drain mismatch: {other:?}"),
            }
        }
    }
}

// ---------------------------------------------------------------------
// Engine level: golden digests across backend × trace mode, and
// budget-exhaustion stopping points.
// ---------------------------------------------------------------------

/// A contended workload with deliberate same-timestamp batches: 48 jobs
/// across 3 clients, arrival times quantized so several jobs share each
/// arrival instant.
fn build_workload(queue: QueueBackend, trace_mode: TraceMode) -> Engine {
    let mut e = Engine::with_options(
        Testbed::intel_server(),
        Policy::FairShare,
        EngineOptions {
            queue,
            trace_mode,
            capacity_hint: 48,
        },
    );
    let clients: Vec<_> = (0..3).map(|i| e.register_client(format!("c{i}"))).collect();
    let kernel = KernelDesc::new("k", 288, 256, 80, 8 * 1024, 1e8, 5e6);
    for j in 0..48usize {
        e.submit(
            JobSpec {
                client: clients[j % clients.len()],
                label: format!("j{j}"),
                phases: vec![Phase::gpu("p", 0.0, vec![kernel.clone(); 5])],
            },
            (j / 4) as f64 * 2e-3, // 4 jobs share every arrival instant
        );
    }
    e
}

#[test]
fn engine_digest_identical_across_backends_and_trace_modes() {
    let mut baseline = build_workload(QueueBackend::Heap, TraceMode::Full);
    baseline.run_all();
    let base_digest = baseline.current_trace_digest();
    let base_rows = baseline.trace().len();
    let base_now = baseline.now().to_bits();
    assert!(base_rows > 0, "workload must record trace rows");
    assert_eq!(base_digest, trace_digest(baseline.trace()));

    for queue in QueueBackend::ALL {
        for trace_mode in [TraceMode::Full, TraceMode::Streaming { window: 16 }] {
            let mut e = build_workload(queue, trace_mode);
            e.run_all();
            assert_eq!(
                e.current_trace_digest(),
                base_digest,
                "digest must match heap/full baseline ({queue:?}, {trace_mode:?})"
            );
            assert_eq!(e.now().to_bits(), base_now, "({queue:?}, {trace_mode:?})");
            assert_eq!(e.take_completed().len(), 48, "({queue:?}, {trace_mode:?})");
            if let Some(st) = e.streaming_trace() {
                assert_eq!(
                    st.rows_recorded(),
                    base_rows as u64,
                    "streaming must fold exactly the rows full mode materializes"
                );
            } else {
                assert_eq!(e.trace().len(), base_rows);
            }
        }
    }
}

/// Same-timestamp events are applied as one batch with a single trace row,
/// so the trace is strictly shorter than the event count on this workload.
#[test]
fn batched_application_collapses_same_time_events() {
    let mut e = build_workload(QueueBackend::Heap, TraceMode::Full);
    e.run_all();
    let events = e.events_processed();
    let rows = e.trace().len() as u64;
    assert!(rows > 0 && events > rows, "expected batching: {rows} rows for {events} events");
}

/// Budget exhaustion mid-run (including mid same-timestamp batch, which
/// the quantized arrivals guarantee for small budgets) is a pure function
/// of the pop order: both backends and both trace modes stop at the same
/// event count, the same virtual-time bits, and the same partial digest.
#[test]
fn budget_exhaustion_stops_identically_across_backends() {
    for budget in [7u64, 64, 301] {
        let run = |queue: QueueBackend, trace_mode: TraceMode| {
            let mut e = build_workload(queue, trace_mode);
            e.set_event_budget(Some(budget));
            let err = e
                .run_until_budgeted(f64::INFINITY)
                .expect_err("budget must exhaust");
            assert_eq!(
                err,
                EngineError::Budget(BudgetExhausted::Events { budget, at: e.now() })
            );
            (e.events_processed(), e.now().to_bits(), e.current_trace_digest())
        };
        let baseline = run(QueueBackend::Heap, TraceMode::Full);
        assert_eq!(baseline.0, budget);
        for queue in QueueBackend::ALL {
            for trace_mode in [TraceMode::Full, TraceMode::Streaming { window: 8 }] {
                assert_eq!(
                    run(queue, trace_mode),
                    baseline,
                    "budget {budget} stop point must match ({queue:?}, {trace_mode:?})"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------
// Streaming memory bound: peak materialized rows are O(window).
// ---------------------------------------------------------------------

#[test]
fn streaming_trace_memory_is_bounded_by_window() {
    const WINDOW: usize = 32;
    let mut e = build_workload(QueueBackend::Wheel, TraceMode::Streaming { window: WINDOW });
    e.run_all();
    let st = e.streaming_trace().expect("streaming recorder");
    let rows = st.rows_recorded();
    assert!(
        rows as usize > WINDOW * 4,
        "workload too small to prove the bound: {rows} rows"
    );
    assert_eq!(st.tail_len(), WINDOW, "ring holds exactly the tail window");
    // VecDeque may round its allocation up, but the reservation must stay
    // O(window) — not O(rows_recorded).
    assert!(
        st.ring_row_capacity() <= WINDOW * 4,
        "ring capacity {} grew past O(window={WINDOW}) after {rows} rows",
        st.ring_row_capacity()
    );
    // The materialized tail is the last WINDOW rows of the equivalent full
    // trace, byte-for-byte.
    let mut full = build_workload(QueueBackend::Wheel, TraceMode::Full);
    full.run_all();
    assert_eq!(full.trace().len() as u64, rows);
    let tail_start = full.trace().len() - WINDOW;
    let tail = e.take_trace();
    assert_eq!(tail.len(), WINDOW);
    for i in 0..WINDOW {
        let a = tail.get(i).to_sample();
        let b = full.trace().get(tail_start + i).to_sample();
        assert_eq!(a.t.to_bits(), b.t.to_bits(), "tail row {i}");
        assert_eq!(a, b, "tail row {i}");
    }
}

// ---------------------------------------------------------------------
// Scenario level: the matrix report's golden digests are invariant under
// queue backend, trace mode, and `--jobs`, across the default/chaos/
// workflow slices.
// ---------------------------------------------------------------------

#[test]
fn scenario_digests_invariant_under_backend_trace_mode_and_jobs() {
    let all = MatrixAxes::default_matrix(42).expand();
    let pick = |pred: &dyn Fn(&str) -> bool| -> ScenarioSpec {
        all.iter()
            .find(|s| pred(&s.name))
            .unwrap_or_else(|| panic!("no matching spec in the default matrix"))
            .clone()
    };
    // One spec per slice: a flat app-mix row, a chaos row, a workflow row.
    let specs = vec![
        pick(&|n| n.starts_with("mix=")),
        pick(&|n| n.starts_with("chaos=")),
        pick(&|n| n.starts_with("workflow=")),
    ];
    let digests = |specs: &[ScenarioSpec], jobs: usize| -> Vec<(String, u64)> {
        let report = run_specs_jobs(specs, 42, jobs).unwrap();
        report
            .scenarios
            .iter()
            .map(|s| {
                assert!(s.error.is_none(), "{}: {:?}", s.name, s.error);
                (s.name.clone(), s.trace_digest)
            })
            .collect()
    };
    let with = |queue: Option<QueueBackend>, mode: Option<TraceMode>| -> Vec<ScenarioSpec> {
        specs
            .iter()
            .cloned()
            .map(|mut s| {
                s.event_queue = queue;
                s.trace_mode = mode;
                s
            })
            .collect()
    };

    let baseline = digests(&specs, 1);
    assert_eq!(baseline.len(), 3);
    for (name, d) in &baseline {
        assert_ne!(*d, 0, "{name}: zero digest");
    }

    // Parallel execution does not perturb the digests.
    assert_eq!(digests(&specs, 4), baseline, "--jobs 4 baseline");
    // Timer wheel reproduces the heap's golden traces.
    assert_eq!(
        digests(&with(Some(QueueBackend::Wheel), None), 1),
        baseline,
        "wheel backend"
    );
    // Streaming folds to the same digest the full trace hashes to.
    assert_eq!(
        digests(&with(None, Some(TraceMode::Streaming { window: 64 })), 1),
        baseline,
        "streaming trace mode"
    );
    // Both knobs together, under parallel execution.
    assert_eq!(
        digests(
            &with(
                Some(QueueBackend::Wheel),
                Some(TraceMode::Streaming { window: 64 })
            ),
            4
        ),
        baseline,
        "wheel + streaming at --jobs 4"
    );
}
