//! Integration tests for the detlint static-analysis pass.
//!
//! Each fixture under `tests/lint_fixtures/` is a miniature repository
//! (its own `rust/src` tree), so path-scoped rules see realistic relative
//! paths. The meta-test at the bottom runs the lint over this repository
//! itself — the tree must ship clean, with every suppression justified.

use std::path::{Path, PathBuf};

use consumerbench::analysis::{run_lint, LintReport};
use consumerbench::cli::run_cli;

fn fixture_root(case: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("lint_fixtures")
        .join(case)
}

fn lint_fixture(case: &str) -> LintReport {
    run_lint(&fixture_root(case)).expect("fixture lint run")
}

fn rule_lines(report: &LintReport) -> Vec<(&str, usize)> {
    report
        .diagnostics
        .iter()
        .map(|d| (d.rule, d.line))
        .collect()
}

#[test]
fn unordered_iteration_fires_in_digest_scope() {
    let report = lint_fixture("unordered");
    assert_eq!(
        rule_lines(&report),
        vec![
            ("no-unordered-iteration", 3),
            ("no-unordered-iteration", 5),
            ("no-unordered-iteration", 6),
        ],
        "{report:?}"
    );
    assert!(report.diagnostics[0].file.ends_with("rust/src/gpusim/bad.rs"));
}

#[test]
fn wall_clock_fires_everywhere() {
    let report = lint_fixture("wall_clock");
    assert_eq!(
        rule_lines(&report),
        vec![
            ("no-wall-clock", 4),
            ("no-wall-clock", 7),
            ("no-wall-clock", 8),
        ],
        "{report:?}"
    );
}

#[test]
fn poisonable_unwrap_fires_but_recovery_pattern_is_exempt() {
    let report = lint_fixture("poisonable");
    assert_eq!(
        rule_lines(&report),
        vec![("no-poisonable-unwrap", 6), ("no-poisonable-unwrap", 11)],
        "{report:?}"
    );
}

#[test]
fn float_order_fires_on_hash_backed_sum_only() {
    let report = lint_fixture("float_order");
    assert_eq!(
        rule_lines(&report),
        vec![("no-float-order-hazard", 7)],
        "the Vec-rooted sum on line 11 must not fire: {report:?}"
    );
}

#[test]
fn ambient_entropy_fires_on_tokens_and_literal_seeds() {
    let report = lint_fixture("entropy");
    assert_eq!(
        rule_lines(&report),
        vec![("no-ambient-entropy", 7), ("no-ambient-entropy", 17)],
        "the seed-derived stream on line 12 must not fire: {report:?}"
    );
}

#[test]
fn clean_fixture_passes() {
    let report = lint_fixture("clean");
    assert!(report.is_clean(), "{report:?}");
    assert_eq!(report.suppressions_honored, 0);
}

#[test]
fn justified_suppression_is_honored() {
    let report = lint_fixture("suppressed");
    assert!(report.is_clean(), "{report:?}");
    assert_eq!(report.suppressions_honored, 1);
}

#[test]
fn bare_suppression_is_rejected_and_violation_survives() {
    let report = lint_fixture("unjustified");
    assert_eq!(
        rule_lines(&report),
        vec![("bad-suppression", 4), ("no-wall-clock", 5)],
        "{report:?}"
    );
    assert_eq!(report.suppressions_honored, 0);
}

#[test]
fn drifted_pins_flag_both_sites() {
    let report = lint_fixture("pin_drift");
    assert_eq!(
        rule_lines(&report),
        vec![("pin-drift", 3), ("pin-drift", 3)],
        "{report:?}"
    );
    let files: Vec<&str> = report.diagnostics.iter().map(|d| d.file.as_str()).collect();
    assert!(files[0].ends_with("a.rs") && files[1].ends_with("b.rs"), "{files:?}");
}

#[test]
fn unanchored_pin_is_flagged_boundary_aware() {
    // The file contains `120`, which must not anchor a pin of `12`.
    let report = lint_fixture("pin_anchor");
    assert_eq!(rule_lines(&report), vec![("pin-drift", 4)], "{report:?}");
    assert!(report.diagnostics[0].message.contains("unanchored"));
}

#[test]
fn schema_marker_drift_flags_both_sites() {
    let report = lint_fixture("marker_drift");
    assert_eq!(
        rule_lines(&report),
        vec![("pin-drift", 4), ("pin-drift", 4)],
        "{report:?}"
    );
    assert!(report.diagnostics[0]
        .message
        .contains("consumerbench_scenario_matrix"));
}

#[test]
fn bench_key_drift_flags_missing_and_stale_entries() {
    let report = lint_fixture("bench_keys");
    assert_eq!(report.diagnostics.len(), 2, "{report:?}");
    assert!(report
        .diagnostics
        .iter()
        .any(|d| d.file.ends_with("BENCH.json") && d.message.contains("gamma_rate")));
    assert!(report
        .diagnostics
        .iter()
        .any(|d| d.file.ends_with("microbench.rs") && d.message.contains("beta_rate")));
}

#[test]
fn cli_lint_exits_nonzero_on_a_violation_fixture() {
    let root = fixture_root("wall_clock");
    let args: Vec<String> = vec![
        "lint".to_string(),
        "--root".to_string(),
        root.to_string_lossy().into_owned(),
    ];
    let mut out = Vec::new();
    let r = run_cli(&args, &mut out);
    let text = String::from_utf8(out).unwrap();
    assert!(r.is_err(), "{text}");
    assert!(text.contains("no-wall-clock"), "{text}");
}

#[test]
fn the_repository_itself_lints_clean() {
    // The acceptance criterion: `consumerbench lint` exits 0 on this tree,
    // and every suppression carries a justification (an unjustified one
    // would surface as a bad-suppression diagnostic and fail this test).
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate dir has a parent")
        .to_path_buf();
    let report = run_lint(&root).expect("lint over the real tree");
    let rendered: Vec<String> = report.diagnostics.iter().map(|d| d.render()).collect();
    assert!(
        report.is_clean(),
        "the repository must ship lint-clean:\n{}",
        rendered.join("\n")
    );
    assert!(
        report.files_scanned >= 40,
        "walker saw only {} files",
        report.files_scanned
    );
    // The two watchdog sites in coordinator/executor.rs are the documented
    // wall-clock boundary; their justified allows are the only expected
    // suppressions today. More may appear, but never silently many.
    assert!(
        (1..=8).contains(&report.suppressions_honored),
        "unexpected suppression count {}",
        report.suppressions_honored
    );

    // And the CLI wrapper agrees, printing the clean summary.
    let args: Vec<String> = vec![
        "lint".to_string(),
        "--root".to_string(),
        root.to_string_lossy().into_owned(),
    ];
    let mut out = Vec::new();
    let r = run_cli(&args, &mut out);
    let text = String::from_utf8(out).unwrap();
    assert!(r.is_ok(), "{text}");
    assert!(text.contains("lint clean"), "{text}");
}
