//! Unit tests for `gpusim::policy::Policy::schedule` — the SM arbitration
//! invariants each sharing regime must uphold:
//!
//! * **Greedy** starves late small kernels behind a device-filling kernel
//!   (the paper's §4.2 finding) — and never invents SMs.
//! * **Equal partition** conserves the SM sum: per-client `held + granted`
//!   never exceeds the static cap, and idle partitions stay idle.
//! * **Fair share** never grants more than the free capacity, even with
//!   adversarial ready sets, and redistributes leftovers work-conservingly.

use consumerbench::gpusim::policy::{Policy, ReadyKernel};
use consumerbench::gpusim::ClientId;
use consumerbench::prop_assert;
use consumerbench::util::proptest::check;

const TOTAL_SMS: usize = 72;

fn rk(client: usize, t: f64, seq: u64, want: usize) -> ReadyKernel {
    ReadyKernel {
        client: ClientId(client),
        enqueue_time: t,
        seq,
        sms_wanted: want,
    }
}

// ---------------------------------------------------------------- greedy --

#[test]
fn greedy_starves_late_small_kernel_while_device_full() {
    let p = Policy::Greedy;
    // Device-filler arrives first and takes everything …
    let ready = [rk(0, 0.0, 0, TOTAL_SMS), rk(1, 0.5, 1, 2)];
    let grants = p.schedule(&ready, TOTAL_SMS, &[], TOTAL_SMS);
    assert_eq!(grants.len(), 1);
    assert_eq!(grants[0].ready_index, 0);
    assert_eq!(grants[0].sms, TOTAL_SMS);
    // … and while it is resident the small kernel gets nothing at all.
    let held = vec![TOTAL_SMS];
    let waiting = [rk(1, 0.5, 1, 2)];
    let grants = p.schedule(&waiting, 0, &held, TOTAL_SMS);
    assert!(grants.is_empty(), "greedy must starve the late small kernel");
}

#[test]
fn greedy_releases_starved_kernel_once_sms_free() {
    let p = Policy::Greedy;
    let waiting = [rk(1, 0.5, 1, 2)];
    let grants = p.schedule(&waiting, TOTAL_SMS, &[], TOTAL_SMS);
    assert_eq!(grants.len(), 1);
    assert_eq!(grants[0].sms, 2, "small kernel takes only what it wants");
}

#[test]
fn greedy_grants_never_exceed_free_randomized() {
    check("greedy-free-bound", 0x51, 200, |g| {
        let n = g.usize(1, 10);
        let ready: Vec<ReadyKernel> = (0..n)
            .map(|i| rk(g.usize(0, 4), i as f64 * 0.01, i as u64, g.usize(1, 100)))
            .collect();
        let free = g.usize(0, TOTAL_SMS + 1);
        let grants = Policy::Greedy.schedule(&ready, free, &[], TOTAL_SMS);
        let granted: usize = grants.iter().map(|x| x.sms).sum();
        prop_assert!(granted <= free, "granted {granted} > free {free}");
        Ok(())
    });
}

// ----------------------------------------------------- equal partition ----

#[test]
fn equal_partition_sm_sum_invariant() {
    // For every reachable holding state: per-client held + newly granted
    // never exceeds the client's cap, and the grand total never exceeds the
    // device.
    let clients = [ClientId(0), ClientId(1), ClientId(2)];
    let p = Policy::equal_partition(&clients, TOTAL_SMS);
    let cap = TOTAL_SMS / clients.len();
    check("partition-sm-sum", 0x62, 300, |g| {
        let mut held = vec![0usize; clients.len()];
        let mut held_total = 0;
        for &c in &clients {
            let h = g.usize(0, cap + 1);
            held[c.0] = h;
            held_total += h;
        }
        let free = TOTAL_SMS - held_total;
        let n = g.usize(1, 8);
        let ready: Vec<ReadyKernel> = (0..n)
            .map(|i| rk(g.usize(0, clients.len()), i as f64 * 0.01, i as u64, g.usize(1, 100)))
            .collect();
        let grants = p.schedule(&ready, free, &held, TOTAL_SMS);
        let mut after = held.clone();
        for x in &grants {
            after[ready[x.ready_index].client.0] += x.sms;
        }
        for (c, &used) in after.iter().enumerate() {
            prop_assert!(used <= cap, "client {c} holds {used} > cap {cap}");
        }
        let total_after: usize = after.iter().sum();
        prop_assert!(
            total_after <= TOTAL_SMS,
            "SM sum {total_after} > device {TOTAL_SMS}"
        );
        Ok(())
    });
}

#[test]
fn equal_partition_idle_share_stays_idle() {
    // Static MPS semantics: a lone active client is still capped, leaving
    // the idle partitions' SMs unused (the paper's under-utilization).
    let p = Policy::equal_partition(&[ClientId(0), ClientId(1), ClientId(2)], TOTAL_SMS);
    let ready = [rk(0, 0.0, 0, TOTAL_SMS)];
    let grants = p.schedule(&ready, TOTAL_SMS, &[], TOTAL_SMS);
    assert_eq!(grants.len(), 1);
    assert_eq!(grants[0].sms, TOTAL_SMS / 3);
}

#[test]
fn equal_partition_full_client_skipped_not_blocking() {
    let p = Policy::equal_partition(&[ClientId(0), ClientId(1)], TOTAL_SMS);
    let held = vec![TOTAL_SMS / 2]; // client 0 at its cap
    let ready = [rk(0, 0.0, 0, 8), rk(1, 0.1, 1, 8)];
    let grants = p.schedule(&ready, TOTAL_SMS / 2, &held, TOTAL_SMS);
    assert_eq!(grants.len(), 1);
    assert_eq!(grants[0].ready_index, 1, "capped client must not block others");
}

// ------------------------------------------------------------ fair share --

#[test]
fn fair_share_never_grants_more_than_capacity() {
    check("fair-share-capacity", 0x73, 300, |g| {
        let n_clients = g.usize(1, 6);
        let n = g.usize(1, 12);
        let ready: Vec<ReadyKernel> = (0..n)
            .map(|i| {
                rk(
                    g.usize(0, n_clients),
                    i as f64 * 0.001,
                    i as u64,
                    g.usize(1, TOTAL_SMS + 10),
                )
            })
            .collect();
        let mut held = vec![0usize; n_clients];
        let mut held_total = 0;
        for c in 0..n_clients {
            let h = g.usize(0, 16);
            if h > 0 && held_total + h <= TOTAL_SMS {
                held[c] = h;
                held_total += h;
            }
        }
        let free = TOTAL_SMS - held_total;
        let grants = Policy::FairShare.schedule(&ready, free, &held, TOTAL_SMS);
        let granted: usize = grants.iter().map(|x| x.sms).sum();
        prop_assert!(
            granted <= free,
            "fair share granted {granted} > free {free}"
        );
        prop_assert!(
            granted + held_total <= TOTAL_SMS,
            "fair share overcommitted the device"
        );
        // No duplicate grants.
        let mut seen = std::collections::BTreeSet::new();
        for x in &grants {
            prop_assert!(seen.insert(x.ready_index), "duplicate grant");
        }
        Ok(())
    });
}

#[test]
fn fair_share_redistributes_leftover_to_waiting_kernels() {
    // Two active clients → fair cap 36 each; client 0's second kernel can
    // still pick up leftovers after both caps are honored (work
    // conservation, unlike the static partition).
    let ready = [
        rk(0, 0.0, 0, TOTAL_SMS),
        rk(1, 0.1, 1, 10),
        rk(0, 0.2, 2, TOTAL_SMS),
    ];
    let grants = Policy::FairShare.schedule(&ready, TOTAL_SMS, &[], TOTAL_SMS);
    let granted: usize = grants.iter().map(|x| x.sms).sum();
    assert!(granted <= TOTAL_SMS);
    // First kernel gets the cap (36), second its want (10), and the third
    // takes from the 26 leftover in pass 2.
    assert_eq!(grants[0].sms, 36);
    assert_eq!(grants[1].sms, 10);
    assert!(
        grants.iter().any(|x| x.ready_index == 2),
        "leftover SMs must be redistributed to waiting kernels"
    );
}
