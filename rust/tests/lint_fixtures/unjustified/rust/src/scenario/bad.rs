//! Fixture: a bare allow is itself a diagnostic AND the violation fires.

pub fn stamp() -> u64 {
    // detlint: allow(no-wall-clock)
    let t = std::time::Instant::now();
    let _ = t;
    0
}
