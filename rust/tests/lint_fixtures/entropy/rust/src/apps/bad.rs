//! Fixture: ambient entropy and literal-seeded streams; a stream derived
//! from a caller-supplied seed is fine.

use crate::util::Rng;

pub fn bad_seed() -> u64 {
    let mut rng = Rng::new(0xDEAD_BEEF);
    rng.next_u64()
}

pub fn good_seed(seed: u64) -> u64 {
    let mut rng = Rng::new(seed ^ 0x9E37_79B9_7F4A_7C15);
    rng.next_u64()
}

pub fn hasher_state() {
    let state = RandomState::new();
    let _ = state;
}
