//! Fixture: a report schema marker emitted with one version …

pub fn emit(out: &mut String) {
    out.push_str("  \"consumerbench_scenario_matrix\": 2,\n");
}
