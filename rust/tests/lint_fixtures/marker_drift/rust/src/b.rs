//! Fixture: … and asserted with another.

pub fn check(json: &str) -> bool {
    json.contains("\"consumerbench_scenario_matrix\": 3")
}
