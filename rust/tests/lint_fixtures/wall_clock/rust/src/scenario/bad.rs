//! Fixture: host-clock reads outside the watchdog boundary.

pub fn stamp() -> std::time::Instant {
    std::time::Instant::now()
}

pub fn epoch() -> std::time::SystemTime {
    std::time::SystemTime::now()
}
