//! Fixture: float reduction over a hash-iterated source; a Vec-rooted
//! reduction of the same shape is fine.

use std::collections::HashMap;

pub fn skewed(weights: HashMap<u32, f64>) -> f64 {
    weights.values().sum::<f64>()
}

pub fn stable(rows: Vec<f64>) -> f64 {
    rows.iter().sum::<f64>()
}
