//! Fixture: a justified suppression silences the diagnostic.

pub fn watchdog_deadline() -> std::time::Instant {
    // detlint: allow(no-wall-clock) -- fixture boundary: host time is only
    // used to arm a timeout and never reaches a digest
    std::time::Instant::now()
}
