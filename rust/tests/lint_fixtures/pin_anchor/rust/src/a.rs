//! Fixture: the pinned literal is gone from the file (the `120` below
//! must not anchor the pin — anchoring is identifier-boundary-aware).

// detlint: pin(demo-count: 12)
pub const DEMO_COUNT: usize = 120;
