//! Fixture: poisonable lock unwraps; the recovery pattern is exempt.

use std::sync::Mutex;

pub fn drain(m: &Mutex<Vec<u64>>) -> usize {
    let held = m.lock().unwrap();
    held.len()
}

pub fn peek(m: &Mutex<Vec<u64>>) -> usize {
    m.lock().expect("poisoned").len()
}

pub fn recovering(m: &Mutex<Vec<u64>>) -> usize {
    m.lock().unwrap_or_else(|e| e.into_inner()).len()
}
