//! Fixture: bench entries drifted from the committed BENCH.json.

struct Entry {
    name: &'static str,
}

const ENTRIES: &[Entry] = &[
    Entry { name: "alpha_rate" },
    Entry { name: "beta_rate" },
];
