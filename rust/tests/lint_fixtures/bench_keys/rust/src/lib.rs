//! Fixture filler: keeps the bench_keys fixture a complete mini-repo.
