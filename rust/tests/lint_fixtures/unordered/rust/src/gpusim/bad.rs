//! Fixture: std hash collections in a digest-affecting module.

use std::collections::HashMap;

pub fn routing() -> HashMap<u64, usize> {
    HashMap::new()
}
