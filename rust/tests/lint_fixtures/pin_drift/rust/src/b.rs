//! Fixture: the other side of the drifted pin.

// detlint: pin(demo-count: 9)
pub fn check(n: usize) {
    assert_eq!(n, 9);
}
