//! Fixture: one side of a drifted pin.

// detlint: pin(demo-count: 7)
pub const DEMO_COUNT: usize = 7;
