//! Fixture: a digest-scope module with no hazards. Banned names appear
//! only in prose (HashMap, Instant::now, SystemTime, thread_rng), string
//! literals, and `#[cfg(test)]` code — none of which may fire.

use std::collections::BTreeMap;

pub fn table() -> BTreeMap<&'static str, u64> {
    let mut m = BTreeMap::new();
    m.insert("HashMap", 1);
    m.insert(r#"Instant::now "quoted""#, 2);
    m
}

pub fn lifetime_soup<'a>(x: &'a str) -> (&'a str, char, u8) {
    (x, '\'', b'"')
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    #[test]
    fn tests_may_use_banned_constructs() {
        let t = std::time::Instant::now();
        let mut h = HashMap::new();
        h.insert(1u8, t);
        let m = std::sync::Mutex::new(0u8);
        let _ = m.lock().unwrap();
        assert_eq!(table().len(), 2);
    }
}
