//! Property-based tests over the simulator, scheduler, and coordinator
//! invariants, using the in-repo mini-framework (`util::proptest`).
//!
//! Triage note (scenario-matrix PR): this suite was failing in the seed
//! only because the crate could not build (missing `Cargo.toml`, ungated
//! `xla` dependency in `runtime/`). No property or seed below was changed;
//! see `tests/policy_schedule.rs` and `tests/golden_trace.rs` for the
//! schedule-invariant and determinism coverage added on top.

use consumerbench::apps::models::llama_3_2_3b;
use consumerbench::coordinator::config::WorkflowNodeConfig;
use consumerbench::coordinator::Dag;
use consumerbench::gpusim::engine::{CpuWork, Engine, JobSpec, MemOp, Phase};
use consumerbench::gpusim::kernel::{occupancy, KernelDesc};
use consumerbench::gpusim::policy::{Policy, ReadyKernel};
use consumerbench::gpusim::profiles::{rtx6000, Testbed};
use consumerbench::gpusim::vram::VramAllocator;
use consumerbench::gpusim::ClientId;
use consumerbench::prop_assert;
use consumerbench::server::{
    InferenceServer, KvCacheManager, KvPlacement, ServerConfig, ServerProfile, ServerRequest,
    ServerTuning,
};
use consumerbench::util::proptest::{check, Gen};

fn random_kernel(g: &mut Gen) -> KernelDesc {
    KernelDesc::new(
        "prop",
        g.usize(1, 5000),
        *g.pick(&[32, 64, 128, 256, 512]),
        g.usize(16, 255),
        g.usize(0, 64 * 1024 + 1).min(64 * 1024) / 16 * 16,
        g.f64(1e3, 1e12),
        g.f64(1e3, 1e9),
    )
}

#[test]
fn prop_occupancy_bounds_and_monotonicity() {
    let gpu = rtx6000();
    check("occupancy-bounds", 0xA1, 300, |g| {
        let k = random_kernel(g);
        let Ok(occ) = occupancy(&k, &gpu) else {
            return Ok(()); // launch error is a valid outcome for huge blocks
        };
        prop_assert!(occ.blocks_per_sm >= 1, "no resident blocks");
        prop_assert!(
            (0.0..=1.0).contains(&occ.occupancy),
            "occupancy {} out of range",
            occ.occupancy
        );
        // More registers can never increase occupancy.
        if k.regs_per_thread < 250 {
            let mut k2 = k.clone();
            k2.regs_per_thread += 5;
            if let Ok(occ2) = occupancy(&k2, &gpu) {
                prop_assert!(
                    occ2.occupancy <= occ.occupancy + 1e-12,
                    "occupancy rose with registers"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_policies_never_overcommit() {
    check("policy-overcommit", 0xB2, 300, |g| {
        let total = 72;
        let n_clients = g.usize(1, 5);
        let n_ready = g.usize(1, 12);
        let ready: Vec<ReadyKernel> = (0..n_ready)
            .map(|i| ReadyKernel {
                client: ClientId(g.usize(0, n_clients)),
                enqueue_time: i as f64 * 0.001,
                seq: i as u64,
                sms_wanted: g.usize(1, 73),
            })
            .collect();
        // Pre-existing holdings never exceed the per-client cap (the only
        // states reachable through the policy itself).
        let cap = total / n_clients;
        let mut held = vec![0usize; n_clients];
        let mut held_total = 0;
        for c in 0..n_clients {
            let h = g.usize(0, cap.min(20) + 1);
            if h > 0 && held_total + h <= total {
                held[c] = h;
                held_total += h;
            }
        }
        let free = total - held_total;
        let policies = [
            Policy::Greedy,
            Policy::equal_partition(
                &(0..n_clients).map(ClientId).collect::<Vec<_>>(),
                total,
            ),
            Policy::FairShare,
        ];
        for p in &policies {
            let grants = p.schedule(&ready, free, &held, total);
            let granted: usize = grants.iter().map(|x| x.sms).sum();
            prop_assert!(granted <= free, "{p}: granted {granted} > free {free}");
            // No ready kernel granted twice.
            let mut seen = std::collections::BTreeSet::new();
            for x in &grants {
                prop_assert!(seen.insert(x.ready_index), "{p}: duplicate grant");
            }
            // Partition: per-client holdings never exceed caps.
            if let Policy::Partition(caps) = p {
                let mut after = held.clone();
                for x in &grants {
                    after[ready[x.ready_index].client.0] += x.sms;
                }
                for (c, cap) in caps {
                    let used = after.get(c.0).copied().unwrap_or(0);
                    prop_assert!(used <= *cap, "partition cap violated: {used} > {cap}");
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_engine_conserves_resources_and_time() {
    check("engine-conservation", 0xC3, 60, |g| {
        let tb = Testbed::intel_server();
        let policy = match g.usize(0, 3) {
            0 => Policy::Greedy,
            1 => Policy::FairShare,
            _ => Policy::equal_partition(&[ClientId(0), ClientId(1)], 72),
        };
        let mut e = Engine::new(tb, policy);
        let a = e.register_client("a");
        let b = e.register_client("b");
        let n_jobs = g.usize(1, 12);
        for i in 0..n_jobs {
            let client = if g.bool() { a } else { b };
            let n_phases = g.usize(1, 4);
            let phases: Vec<Phase> = (0..n_phases)
                .map(|_| {
                    if g.bool() {
                        let n_kernels = g.usize(1, 6);
                        Phase::gpu(
                            "p",
                            g.f64(0.0, 0.01),
                            (0..n_kernels).map(|_| random_kernel(g)).collect(),
                        )
                    } else {
                        Phase::cpu(
                            "c",
                            g.f64(0.0, 0.01),
                            CpuWork {
                                flops: g.f64(1e6, 1e10),
                                bytes: g.f64(1e3, 1e8),
                                threads: g.usize(1, 25),
                            },
                        )
                    }
                })
                .collect();
            e.submit(
                JobSpec {
                    client,
                    label: format!("j{i}"),
                    phases,
                },
                g.f64(0.0, 0.5),
            );
        }
        e.run_all();
        e.check_invariants(); // SM + core conservation
        let done = e.take_completed();
        prop_assert!(done.len() == n_jobs, "{} of {n_jobs} jobs completed", done.len());
        for r in &done {
            if r.error.is_none() {
                prop_assert!(r.end >= r.submit, "job ended before submission");
                for w in r.phases.windows(2) {
                    prop_assert!(w[1].end >= w[0].end, "phase ends non-monotone");
                }
                for p in &r.phases {
                    prop_assert!(p.queue_wait >= -1e-9, "negative queue wait");
                    prop_assert!(p.exec_time >= 0.0, "negative exec time");
                }
            }
        }
        // Trace times are non-decreasing.
        let rows = e.trace().rows();
        for w in rows.windows(2) {
            prop_assert!(w[1].t >= w[0].t, "trace time went backwards");
        }
        for s in rows {
            prop_assert!((0.0..=1.0 + 1e-9).contains(&(s.gpu_smact as f64)), "smact range");
            prop_assert!(s.gpu_smocc <= s.gpu_smact + 1e-6, "SMOCC exceeded SMACT");
        }
        Ok(())
    });
}

#[test]
fn prop_exclusive_is_lower_bound() {
    // A job's latency alone on the device is a lower bound for its latency
    // under any contention (non-preemptive work-conserving policies).
    check("exclusive-lower-bound", 0xD4, 30, |g| {
        let mk_job = |g: &mut Gen, client: ClientId, label: &str| {
            let kernels: Vec<KernelDesc> = (0..g.usize(1, 5)).map(|_| random_kernel(g)).collect();
            JobSpec {
                client,
                label: label.to_string(),
                phases: vec![Phase::gpu("p", 0.0, kernels)],
            }
        };
        // Run job X alone.
        let mut e1 = Engine::new(Testbed::intel_server(), Policy::Greedy);
        let c1 = e1.register_client("x");
        let job_seed = g.rng().next_u64();
        let mut gx = Gen::new(job_seed);
        e1.submit(mk_job(&mut gx, c1, "x"), 0.0);
        e1.run_all();
        let solo = e1.take_completed()[0].latency();

        // Run the identical job with a competitor.
        let mut e2 = Engine::new(Testbed::intel_server(), Policy::Greedy);
        let cx = e2.register_client("x");
        let cy = e2.register_client("y");
        let mut gx = Gen::new(job_seed);
        e2.submit(mk_job(&mut gx, cx, "x"), 0.0);
        e2.submit(mk_job(g, cy, "y"), 0.0);
        e2.run_all();
        let contended = e2
            .take_completed()
            .into_iter()
            .find(|r| r.label == "x")
            .unwrap()
            .latency();
        prop_assert!(
            contended >= solo - 1e-9,
            "contended {contended} < solo {solo}"
        );
        Ok(())
    });
}

#[test]
fn prop_dag_toposort_respects_edges() {
    check("dag-topo", 0xE5, 200, |g| {
        // Random DAG: node i may depend on any subset of nodes < i.
        let n = g.usize(1, 12);
        let nodes: Vec<WorkflowNodeConfig> = (0..n)
            .map(|i| {
                let deps: Vec<String> = (0..i)
                    .filter(|_| g.bool() && g.bool()) // sparse
                    .map(|d| format!("n{d}"))
                    .collect();
                WorkflowNodeConfig {
                    id: format!("n{i}"),
                    uses: format!("task{i}"),
                    depend_on: deps,
                    background: g.bool(),
                }
            })
            .collect();
        let dag = Dag::build(&nodes).map_err(|e| format!("build failed: {e}"))?;
        let order = dag.toposort().map_err(|e| format!("{e}"))?;
        prop_assert!(order.len() == n, "toposort dropped nodes");
        let pos: Vec<usize> = {
            let mut p = vec![0; n];
            for (idx, &node) in order.iter().enumerate() {
                p[node] = idx;
            }
            p
        };
        for i in 0..n {
            for &d in dag.deps(i) {
                prop_assert!(pos[d] < pos[i], "dep {d} not before {i}");
            }
        }
        // Depth is bounded by node count.
        prop_assert!(dag.depth() <= n, "depth > n");
        Ok(())
    });
}

#[test]
fn prop_vram_allocator_balances() {
    check("vram-balance", 0xF6, 200, |g| {
        let cap = 1u64 << 30;
        let mut v = VramAllocator::new(cap);
        let mut live: Vec<(consumerbench::gpusim::vram::AllocId, u64)> = Vec::new();
        let mut expected: u64 = 0;
        for _ in 0..g.usize(1, 60) {
            if g.bool() || live.is_empty() {
                let bytes = g.u64(1, cap / 8);
                match v.alloc("c", "b", bytes) {
                    Ok(id) => {
                        live.push((id, bytes));
                        expected += bytes;
                    }
                    Err(_) => {
                        prop_assert!(
                            expected + bytes > cap,
                            "OOM with only {expected} + {bytes} of {cap} used"
                        );
                    }
                }
            } else {
                let i = g.usize(0, live.len());
                let (id, bytes) = live.remove(i);
                v.free(id);
                expected -= bytes;
            }
            prop_assert!(v.used() == expected, "used {} != expected {}", v.used(), expected);
            prop_assert!(v.used() <= cap, "over capacity");
        }
        Ok(())
    });
}

#[test]
fn prop_kv_cache_accounting() {
    check("kv-accounting", 0x17, 200, |g| {
        let capacity = g.usize(100, 10_000);
        let mut m = KvCacheManager::new(KvPlacement::Gpu, 1024, capacity);
        let mut live: Vec<(u64, usize)> = Vec::new();
        let mut next = 0u64;
        let mut expected = 0usize;
        for _ in 0..g.usize(1, 80) {
            match g.usize(0, 3) {
                0 => {
                    let tokens = g.usize(1, 200);
                    match m.alloc_seq(next, tokens) {
                        Ok(()) => {
                            live.push((next, tokens));
                            expected += tokens;
                        }
                        Err(_) => prop_assert!(
                            expected + tokens > capacity,
                            "rejected alloc that fit"
                        ),
                    }
                    next += 1;
                }
                1 if !live.is_empty() => {
                    let i = g.usize(0, live.len());
                    let tokens = g.usize(1, 50);
                    let (seq, ref mut held) = live[i];
                    if m.extend_seq(seq, tokens).is_ok() {
                        *held += tokens;
                        expected += tokens;
                    } else {
                        prop_assert!(expected + tokens > capacity, "rejected extend that fit");
                    }
                }
                _ if !live.is_empty() => {
                    let i = g.usize(0, live.len());
                    let (seq, tokens) = live.remove(i);
                    let freed = m.free_seq(seq).map_err(|e| format!("{e}"))?;
                    prop_assert!(freed == tokens, "freed {freed} != {tokens}");
                    expected -= tokens;
                }
                _ => {}
            }
            prop_assert!(
                m.used_tokens() == expected,
                "used {} != expected {}",
                m.used_tokens(),
                expected
            );
        }
        Ok(())
    });
}

#[test]
fn prop_partition_latency_bounded_by_exclusive_share() {
    // Under an equal partition, a client's kernel on cap SMs should take no
    // longer than the same kernel granted exactly cap SMs exclusively.
    check("partition-share-bound", 0x28, 80, |g| {
        let gpu = rtx6000();
        let k = random_kernel(g);
        if occupancy(&k, &gpu).is_err() {
            return Ok(());
        }
        let cap = 24;
        // The engine grants min(wanted, cap) SMs — a small grid cannot use
        // the whole partition, so bound against the grant it will get.
        let wanted = consumerbench::gpusim::kernel::sms_wanted(&k, &gpu).unwrap();
        let d_cap =
            consumerbench::gpusim::kernel::duration(&k, &gpu, wanted.min(cap)).unwrap();
        let mut e = Engine::new(Testbed::intel_server(), Policy::Greedy);
        let a = e.register_client("a");
        e.set_policy(Policy::Partition([(a, cap)].into_iter().collect()));
        e.submit(
            JobSpec {
                client: a,
                label: "solo".into(),
                phases: vec![Phase::gpu("p", 0.0, vec![k])],
            },
            0.0,
        );
        e.run_all();
        let lat = e.take_completed()[0].latency();
        prop_assert!(
            lat <= d_cap * 1.01 + 1e-6,
            "partitioned latency {lat} > capped-exclusive {d_cap}"
        );
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Adaptive serving layer: unified-batching and reconfiguration invariants
// ---------------------------------------------------------------------

/// Random server tuning within the ranges the adaptive controller uses.
fn random_tuning(g: &mut Gen) -> ServerTuning {
    ServerTuning {
        kv_placement: if g.bool() {
            KvPlacement::Gpu
        } else {
            KvPlacement::Cpu
        },
        n_slots: g.usize(1, 7),
        batch_size: *g.pick(&[32, 128, 512]),
    }
}

/// Fresh engine + started server with a small context window (so KV
/// migrations always fit next to the weights on the 24 GiB testbed).
fn started_server(tuning: ServerTuning) -> (Engine, InferenceServer) {
    let cfg = ServerConfig {
        profile: ServerProfile {
            model: llama_3_2_3b(),
            context_window: 4096,
        },
        tuning,
    };
    let mut e = Engine::new(Testbed::intel_server(), Policy::Greedy);
    let c = e.register_client("llama-server");
    let mut s = InferenceServer::new(cfg, c);
    s.start(&mut e, 0.0);
    e.run_all();
    e.take_completed();
    (e, s)
}

#[test]
fn prop_unified_batch_invariants() {
    check("server-unified-batch", 0xC3, 40, |g| {
        let tuning = random_tuning(g);
        let (mut e, mut s) = started_server(tuning);
        let n_req = g.usize(1, 10);
        for i in 0..n_req {
            s.enqueue(
                ServerRequest {
                    id: i as u64,
                    app: "Chatbot",
                    prompt_tokens: g.usize(1, 900),
                    output_tokens: g.usize(1, 24),
                },
                e.now(),
            );
        }
        let mut guard = 0u32;
        loop {
            guard += 1;
            prop_assert!(guard < 100_000, "server did not converge");
            let before = s.iterations();
            s.pump(&mut e, e.now());
            if s.iterations() > before {
                // The just-launched batch equals the current plan (slot
                // state only advances when the iteration completes).
                let plan = s.plan_batch().expect("in-flight batch must plan");
                prop_assert!(
                    plan.tokens() <= tuning.batch_size,
                    "batch of {} tokens exceeds batch_size {}",
                    plan.tokens(),
                    tuning.batch_size
                );
                let mut seen = std::collections::BTreeSet::new();
                for &slot in &plan.decode_slots {
                    // Exactly one decode token per decoding slot.
                    prop_assert!(seen.insert(slot), "slot {slot} decodes twice");
                }
                for &(slot, chunk) in &plan.prefill {
                    prop_assert!(chunk >= 1, "empty prefill chunk");
                    prop_assert!(
                        seen.insert(slot),
                        "slot {slot} decodes and prefills in one batch"
                    );
                }
            }
            let Some(t) = e.next_event_time() else { break };
            e.run_until(t);
            for r in e.take_completed() {
                s.on_job_done(&r);
            }
            if s.idle() && e.next_event_time().is_none() {
                break;
            }
        }
        let responses = s.take_responses();
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        prop_assert!(
            ids == (0..n_req as u64).collect::<Vec<u64>>(),
            "served ids {ids:?}, expected 0..{n_req}"
        );
        for r in &responses {
            prop_assert!(
                r.end >= r.first_token && r.first_token >= r.submit,
                "response timestamps out of order"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_reconfigure_never_loses_or_duplicates_requests() {
    check("server-reconfigure-chaos", 0xD4, 30, |g| {
        let (mut e, mut s) = started_server(random_tuning(g));
        let n_req = g.usize(2, 14);
        for i in 0..n_req {
            s.enqueue(
                ServerRequest {
                    id: i as u64,
                    app: "Chatbot",
                    prompt_tokens: g.usize(200, 1500),
                    output_tokens: g.usize(1, 16),
                },
                e.now(),
            );
        }
        // Inject reconfigurations mid-prefill/mid-decode: every few event
        // rounds flip the placement, resize slots, and change the batch.
        let reconfig_every = g.usize(2, 7);
        let mut rounds = 0usize;
        let mut requested = 0u32;
        let mut guard = 0u32;
        loop {
            guard += 1;
            prop_assert!(guard < 200_000, "server did not converge");
            s.pump(&mut e, e.now());
            let Some(t) = e.next_event_time() else { break };
            e.run_until(t);
            for r in e.take_completed() {
                s.on_job_done(&r);
            }
            rounds += 1;
            if rounds % reconfig_every == 0 && requested < 20 {
                requested += 1;
                s.reconfigure(&mut e, e.now(), random_tuning(g));
            }
            if s.idle() && e.next_event_time().is_none() {
                break;
            }
        }
        prop_assert!(s.idle(), "server must drain to idle after reconfigs");
        prop_assert!(
            s.queued_requests() == 0 && s.active_slots() == 0,
            "leftover work after drain"
        );
        let responses = s.take_responses();
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        prop_assert!(
            ids == (0..n_req as u64).collect::<Vec<u64>>(),
            "lost/duplicated requests after {requested} reconfigs: {ids:?} (expected 0..{n_req})"
        );
        // The tuning that finally stuck is the last requested one's shape.
        prop_assert!(
            s.tuning().n_slots >= 1 && s.tuning().batch_size >= 32,
            "tuning corrupted: {:?}",
            s.tuning()
        );
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Chaos injection: VRAM conservation under failures + replay determinism
// ---------------------------------------------------------------------

#[test]
fn prop_vram_conserved_after_randomized_mem_op_failures() {
    // Jobs carry multi-op alloc phases sized so that some of them OOM
    // mid-application: the engine's rollback must make every phase
    // all-or-nothing, and the allocator's books must balance regardless of
    // which jobs failed.
    check("vram-conservation-chaos", 0x4A, 60, |g| {
        let mut e = Engine::new(Testbed::intel_server(), Policy::Greedy);
        let a = e.register_client("a");
        let b = e.register_client("b");
        let cap = e.vram().capacity();
        let n_jobs = g.usize(4, 13);
        // Per job: (mem labels+bytes, whether a second phase frees them).
        let mut plans: Vec<(Vec<(String, u64)>, bool)> = Vec::new();
        for i in 0..n_jobs {
            let n_ops = g.usize(1, 4);
            let ops: Vec<(String, u64)> = (0..n_ops)
                .map(|k| (format!("j{i}.{k}"), g.u64(cap / 16, cap / 3)))
                .collect();
            let frees = g.bool();
            let mut phases = vec![Phase::host("prop.alloc", 0.0).with_mem_ops(
                ops.iter()
                    .map(|(label, bytes)| MemOp::Alloc {
                        label: label.clone(),
                        bytes: *bytes,
                    })
                    .collect(),
            )];
            if frees {
                phases.push(Phase::host("prop.free", 0.001).with_mem_ops(
                    ops.iter()
                        .map(|(label, _)| MemOp::Free {
                            label: label.clone(),
                        })
                        .collect(),
                ));
            }
            e.submit(
                JobSpec {
                    client: if g.bool() { a } else { b },
                    label: format!("j{i}"),
                    phases,
                },
                g.f64(0.0, 0.5),
            );
            plans.push((ops, frees));
        }
        e.run_all();
        let done = e.take_completed();
        prop_assert!(done.len() == n_jobs, "{} of {n_jobs} jobs ran", done.len());
        let inv = e.vram().inventory();
        let inv_sum: u64 = inv.iter().map(|(_, _, bytes)| *bytes).sum();
        prop_assert!(
            inv_sum == e.vram().used(),
            "inventory {} != used {}",
            inv_sum,
            e.vram().used()
        );
        let by_client = e.vram().used_by("a") + e.vram().used_by("b");
        prop_assert!(
            by_client == e.vram().used(),
            "per-client sums {} != used {}",
            by_client,
            e.vram().used()
        );
        // Every job's allocations are all-or-nothing: a failed job leaves
        // no partial allocation behind, a successful one that never freed
        // keeps exactly what it asked for.
        for r in &done {
            let i: usize = r.label[1..].parse().unwrap();
            let (ops, frees) = &plans[i];
            let live: Vec<&(String, String, u64)> = inv
                .iter()
                .filter(|(_, label, _)| label.starts_with(&format!("j{i}.")))
                .collect();
            if r.error.is_some() || *frees {
                prop_assert!(
                    live.is_empty(),
                    "job j{i} (failed={}, frees={frees}) leaked {live:?}",
                    r.error.is_some()
                );
            } else {
                let want: u64 = ops.iter().map(|(_, bytes)| *bytes).sum();
                let got: u64 = live.iter().map(|(_, _, bytes)| *bytes).sum();
                prop_assert!(
                    live.len() == ops.len() && got == want,
                    "job j{i} holds {got} of {want} bytes in {} of {} allocations",
                    live.len(),
                    ops.len()
                );
            }
        }
        Ok(())
    });
}

#[test]
fn chaos_slice_replays_byte_identically_and_diverges_with_seed() {
    use consumerbench::scenario::{run_specs_jobs, MatrixAxes, MatrixReport, ScenarioSpec};
    let chaos_specs = |seed: u64| -> Vec<ScenarioSpec> {
        MatrixAxes::default_matrix(seed)
            .expand()
            .into_iter()
            .filter(|s| s.name.starts_with("chaos="))
            .collect()
    };
    let specs = chaos_specs(42);
    assert_eq!(specs.len(), 10, "5 fault classes x static/adaptive");
    let base = run_specs_jobs(&specs, 42, 1).unwrap();
    let json = base.to_json();
    // Same seed: byte-identical across a repeat and across worker counts.
    assert_eq!(
        json,
        run_specs_jobs(&specs, 42, 1).unwrap().to_json(),
        "chaos replay must be deterministic"
    );
    assert_eq!(
        json,
        run_specs_jobs(&specs, 42, 4).unwrap().to_json(),
        "worker count must not change the fault schedule"
    );
    // Different seed: the fault schedule (and hence the traces) diverge.
    let digests = |r: &MatrixReport| -> Vec<u64> {
        r.scenarios.iter().map(|s| s.trace_digest).collect()
    };
    let other = run_specs_jobs(&chaos_specs(7), 7, 4).unwrap();
    assert_ne!(
        digests(&base),
        digests(&other),
        "a different seed must produce different fault timings"
    );
}
