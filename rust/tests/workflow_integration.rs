//! Integration tests: full workflows through config → DAG → executor →
//! report, exercising every coordinator subsystem together.

use consumerbench::coordinator::{generate, run_config_text, to_csv, BenchConfig, Dag};

#[test]
fn fig2_style_workflow_end_to_end() {
    // The paper's Fig. 2 example: DeepResearch on CPU, then ImageGen and a
    // second analysis in parallel, then captions.
    let text = "\
Analysis (DeepResearch):
  model: Llama-3.2-3B
  num_requests: 1
  device: cpu
Creating Cover Art (ImageGen):
  model: SD-3.5-Medium-Turbo
  num_requests: 2
  device: gpu
  slo: 1s
Generating Captions (LiveCaptions):
  model: Whisper-Large-V3-Turbo
  num_requests: 5
  device: gpu
  slo: 2s
workflows:
  analysis_1:
    uses: Analysis (DeepResearch)
  cover_art:
    uses: Creating Cover Art (ImageGen)
    depend_on: [\"analysis_1\"]
  analysis_2:
    uses: Analysis (DeepResearch)
    depend_on: [\"analysis_1\"]
  generate_captions:
    uses: Generating Captions (LiveCaptions)
    depend_on: [\"cover_art\"]
seed: 7
";
    let result = run_config_text(text, None).unwrap();
    assert_eq!(result.nodes.len(), 4);
    // Ordering: analysis_1 before cover_art before captions.
    let a1 = result.node("analysis_1").unwrap();
    let art = result.node("cover_art").unwrap();
    let cc = result.node("generate_captions").unwrap();
    assert!(art.start >= a1.end - 1e-9);
    assert!(cc.start >= art.end - 1e-9);
    // Parallel branch overlaps with cover_art.
    let a2 = result.node("analysis_2").unwrap();
    assert!(a2.start >= a1.end - 1e-9);
    let overlap = art.end.min(a2.end) - art.start.max(a2.start);
    assert!(overlap > 0.0, "parallel branches should overlap");
    // All requests completed and evaluated.
    assert_eq!(art.metrics.len(), 2);
    assert_eq!(cc.metrics.len(), 5);
    // Report renders.
    let report = generate(&result);
    assert!(report.text.contains("analysis_1"));
    let csv = to_csv(&result);
    assert!(csv.lines().count() > 8);
}

#[test]
fn deterministic_end_to_end() {
    let text = "\
Chat (chatbot):
  num_requests: 4
Img (imagegen):
  num_requests: 2
seed: 99
";
    let run = || {
        let r = run_config_text(text, None).unwrap();
        (
            r.makespan,
            r.nodes
                .iter()
                .flat_map(|n| n.metrics.iter().map(|m| m.latency))
                .collect::<Vec<f64>>(),
        )
    };
    let (m1, l1) = run();
    let (m2, l2) = run();
    assert_eq!(m1, m2);
    assert_eq!(l1, l2);
}

#[test]
fn seed_changes_workload() {
    let cfg = |seed: u64| format!("Chat (chatbot):\n  num_requests: 4\nseed: {seed}\n");
    let a = run_config_text(&cfg(1), None).unwrap().makespan;
    let b = run_config_text(&cfg(2), None).unwrap().makespan;
    assert_ne!(a, b);
}

#[test]
fn strategies_produce_different_outcomes() {
    let cfg = |s: &str| {
        format!(
            "Img (imagegen):\n  num_requests: 4\nCc (livecaptions):\n  num_requests: 20\nstrategy: {s}\nseed: 42\n"
        )
    };
    let greedy = run_config_text(&cfg("greedy"), None).unwrap();
    let part = run_config_text(&cfg("partition"), None).unwrap();
    let fair = run_config_text(&cfg("fair_share"), None).unwrap();
    let lc_norm = |r: &consumerbench::coordinator::ScenarioResult| {
        r.node("Cc (livecaptions)").unwrap().mean_normalized()
    };
    // Partitioning must protect LiveCaptions relative to greedy.
    assert!(
        lc_norm(&part) < lc_norm(&greedy),
        "partition {} vs greedy {}",
        lc_norm(&part),
        lc_norm(&greedy)
    );
    // Fair share sits between (work-conserving but non-preemptive).
    assert!(lc_norm(&fair) <= lc_norm(&greedy) + 1e-9);
}

#[test]
fn apple_testbed_runs_all_apps() {
    let text = "\
Chat (chatbot):
  num_requests: 2
Img (imagegen):
  num_requests: 1
Cc (livecaptions):
  num_requests: 5
testbed: macbook_m1_pro
strategy: fair_share
seed: 42
";
    let result = run_config_text(text, None).unwrap();
    assert_eq!(result.nodes.len(), 3);
    for n in &result.nodes {
        assert!(n.failed.is_none(), "{}: {:?}", n.id, n.failed);
        assert!(!n.metrics.is_empty());
    }
    // The M1 draws laptop-class power.
    let mon = consumerbench::monitor::MonitorReport::from_trace(
        &result.trace,
        &result.client_names,
        consumerbench::monitor::DEFAULT_INTERVAL,
        result.gpu_idle_w,
        result.cpu_idle_w,
    );
    assert!(mon.gpu_power.max() <= 31.0, "peak {}", mon.gpu_power.max());
}

#[test]
fn server_shared_by_two_apps() {
    let text = "\
Chat (chatbot):
  num_requests: 4
  server: llama
  slo: [1s, 0.25s]
Research (deepresearch):
  num_requests: 1
  server: llama
servers:
  llama:
    model: Llama-3.2-3B
    context_window: 16384
    kv_placement: gpu
seed: 42
";
    let result = run_config_text(text, None).unwrap();
    let chat = result.node("Chat (chatbot)").unwrap();
    let dr = result.node("Research (deepresearch)").unwrap();
    assert_eq!(chat.metrics.len(), 4);
    assert_eq!(dr.metrics.len(), 1);
    // DeepResearch is the long pole.
    assert!(dr.metrics[0].latency > chat.metrics[0].latency);
}

#[test]
fn config_validation_via_dag() {
    let cfg = BenchConfig::parse(
        "A (chatbot):\n  num_requests: 1\nworkflows:\n  a:\n    uses: A (chatbot)\n",
    )
    .unwrap();
    let dag = Dag::build(&cfg.workflow).unwrap();
    assert_eq!(dag.len(), 1);
    assert_eq!(dag.depth(), 1);
}

#[test]
fn pjrt_runtime_composes_with_executor_when_artifacts_exist() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !consumerbench::runtime::Runtime::available(dir) {
        eprintln!("artifacts absent; skipping PJRT-composition test");
        return;
    }
    let result = run_config_text(
        "Chat (chatbot):\n  num_requests: 2\nImg (imagegen):\n  num_requests: 1\nseed: 1\n",
        Some(dir),
    )
    .unwrap();
    // One PJRT execution per completed request.
    assert_eq!(result.pjrt_calls, 3, "pjrt calls {}", result.pjrt_calls);
}
