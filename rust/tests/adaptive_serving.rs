//! Golden tests for the adaptive serving layer (ISSUE 3 acceptance).
//!
//! Pins three things end-to-end:
//!
//! 1. **The ablation**: in the paper's §4.2.1 contention scenario (Chatbot
//!    + DeepResearch sharing a 128K-context server with a CPU-resident KV
//!    cache), the feedback controller *strictly* improves chat SLO
//!    attainment over the frozen `kv_cpu` configuration, by migrating the
//!    KV region onto the GPU once the misses show up in its window.
//! 2. **Determinism**: adaptive runs replay byte-for-byte — action logs,
//!    reconfiguration counts, and trace digests (which include the
//!    migration's DMA transfer and `MemOp`s) are identical across repeats.
//! 3. **Parallel identity**: a matrix containing `server=adaptive`
//!    scenarios renders byte-identical JSON for `--jobs 1` and `--jobs 4`.

use consumerbench::coordinator::run_config_text;
use consumerbench::gpusim::engine::trace_digest;
use consumerbench::scenario::{run_matrix_jobs, MatrixAxes};

/// The fig6-shaped contention config: 25 chat requests + 2 DeepResearch
/// tasks through one shared server whose KV region starts in CPU DRAM.
/// `adaptive: true` adds the controller block — the only difference.
fn contention_config(adaptive: bool) -> String {
    let controller = if adaptive {
        "controller:\n  epoch: 1s\n  window: 8s\n  target_attainment: 0.9\n"
    } else {
        ""
    };
    format!(
        "\
Chat (chatbot):
  num_requests: 25
  device: gpu
  server: llama
  slo: [1s, 0.25s]
Research (deepresearch):
  num_requests: 2
  device: gpu
  server: llama
servers:
  llama:
    model: Llama-3.2-3B
    context_window: 131072
    kv_placement: cpu
{controller}strategy: greedy
seed: 42
"
    )
}

#[test]
fn adaptive_controller_strictly_improves_chat_attainment() {
    let static_run = run_config_text(&contention_config(false), None).unwrap();
    let adaptive_run = run_config_text(&contention_config(true), None).unwrap();

    let chat = |r: &consumerbench::coordinator::ScenarioResult| {
        r.node("Chat (chatbot)").unwrap().attainment().expect("requests ran")
    };
    let chat_static = chat(&static_run);
    let chat_adaptive = chat(&adaptive_run);

    // The §4.2.1 failure mode is present in the static run …
    assert!(
        chat_static < 0.85,
        "static kv_cpu should miss substantially: attainment {chat_static}"
    );
    // … and the controller strictly recovers attainment.
    assert!(
        chat_adaptive > chat_static,
        "adaptive must strictly improve: {chat_adaptive} vs {chat_static}"
    );
    // The improvement came from actual runtime reconfiguration (KV onload).
    assert!(
        adaptive_run.reconfigurations >= 1,
        "controller never acted; log: {:?}",
        adaptive_run.controller_actions
    );
    assert!(
        adaptive_run
            .controller_actions
            .iter()
            .any(|a| a.contains("migrate-kv")),
        "{:?}",
        adaptive_run.controller_actions
    );
    // The static run stayed static.
    assert_eq!(static_run.reconfigurations, 0);
    assert!(static_run.controller_actions.is_empty());
    // Reconfiguration events perturb the trace: the two runs cannot share
    // a digest.
    assert_ne!(
        trace_digest(&static_run.trace),
        trace_digest(&adaptive_run.trace)
    );
    // Every request was still served exactly once in both runs.
    for result in [&static_run, &adaptive_run] {
        assert_eq!(result.node("Chat (chatbot)").unwrap().metrics.len(), 25);
        assert_eq!(result.node("Research (deepresearch)").unwrap().metrics.len(), 2);
    }
}

#[test]
fn adaptive_runs_replay_byte_for_byte() {
    let a = run_config_text(&contention_config(true), None).unwrap();
    let b = run_config_text(&contention_config(true), None).unwrap();
    assert_eq!(trace_digest(&a.trace), trace_digest(&b.trace));
    assert_eq!(a.reconfigurations, b.reconfigurations);
    assert_eq!(a.controller_actions, b.controller_actions);
    let lats = |r: &consumerbench::coordinator::ScenarioResult| -> Vec<f64> {
        r.nodes
            .iter()
            .flat_map(|n| n.metrics.iter().map(|m| m.latency))
            .collect()
    };
    assert_eq!(lats(&a), lats(&b));
}

/// Chat-only slice of the default matrix: one text mix, two policies, one
/// arrival — four scenarios, two of them adaptive. The workflow slice is
/// dropped (it has its own suites in parallel_matrix/golden_trace).
fn adaptive_axes(seed: u64) -> MatrixAxes {
    let mut axes = MatrixAxes::default_matrix(seed);
    axes.mixes.truncate(1); // chat
    axes.strategies.truncate(2);
    axes.arrivals.truncate(1);
    axes.workflows.clear();
    axes.backends.clear();
    axes.chaos.clear();
    axes
}

#[test]
fn adaptive_matrix_is_byte_identical_across_jobs_and_repeats() {
    let j1 = run_matrix_jobs(&adaptive_axes(42), 1).unwrap().to_json();
    let j4 = run_matrix_jobs(&adaptive_axes(42), 4).unwrap().to_json();
    assert_eq!(j1, j4, "adaptive scenarios must not break --jobs identity");
    let again = run_matrix_jobs(&adaptive_axes(42), 4).unwrap().to_json();
    assert_eq!(j1, again, "same seed must reproduce exactly");
    assert!(j1.contains("\"server_mode\": \"adaptive\""));
    // A different seed diverges (the digests really pin engine behaviour).
    let other = run_matrix_jobs(&adaptive_axes(43), 4).unwrap().to_json();
    assert_ne!(j1, other);
}

#[test]
fn matrix_delta_column_reports_the_ablation() {
    let report = run_matrix_jobs(&adaptive_axes(42), 4).unwrap();
    let deltas = report.adaptive_deltas();
    assert_eq!(deltas.len(), 2, "one pair per adaptive scenario");
    for d in &deltas {
        assert!(!d.base.contains("server="), "{}", d.base);
        assert!((0.0..=1.0).contains(&d.static_min_attainment), "{d:?}");
        assert!((0.0..=1.0).contains(&d.adaptive_min_attainment), "{d:?}");
        assert!(
            (d.delta - (d.adaptive_min_attainment - d.static_min_attainment)).abs() < 1e-12,
            "{d:?}"
        );
    }
    // Static twins never reconfigure; the JSON carries the delta column.
    for s in &report.scenarios {
        if s.server_mode == "static" {
            assert_eq!(s.reconfigurations, 0, "{}", s.name);
        }
    }
    assert!(report.to_json().contains("\"attainment_delta\""));
}
