//! Sweep-supervision resilience suite.
//!
//! Pins the fault-tolerance contract of the scenario matrix: a panicking
//! or budget-exhausted scenario becomes a quarantined report row while
//! every sibling completes; the report stays **byte-identical** across
//! `--jobs 1`, `--jobs 4`, and repeated runs even with quarantined rows in
//! it; and a sweep that is killed mid-run (simulated by truncating the
//! JSONL journal, including mid-line) resumes to a report byte-identical
//! to an uninterrupted one. Stale journal entries — same scenario name,
//! different spec digest — are ignored rather than replayed.

use std::path::PathBuf;

use consumerbench::cli::run_cli;
use consumerbench::coordinator::InjectFailure;
use consumerbench::scenario::{
    run_specs_supervised, MatrixAxes, ScenarioSpec, ScenarioStatus, SweepOptions,
};

/// The flat `mix=chat` slice of the default matrix: a handful of fast
/// scenarios (static + adaptive twins) — enough rows for supervision and
/// resume to be meaningful without a long sweep.
fn chat_slice(seed: u64) -> Vec<ScenarioSpec> {
    let mut specs = MatrixAxes::default_matrix(seed).expand();
    specs.retain(|s| s.name.starts_with("mix=chat/"));
    assert!(specs.len() >= 4, "expected a non-trivial slice, got {}", specs.len());
    specs
}

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!(
        "cb_sweep_resilience_{}_{tag}.jsonl",
        std::process::id()
    ))
}

#[test]
fn panicking_scenario_does_not_abort_siblings_and_report_is_byte_identical() {
    let mut specs = chat_slice(42);
    specs[1].inject_failure = Some(InjectFailure::Panic);
    let opts = |jobs| SweepOptions {
        jobs,
        ..SweepOptions::default()
    };
    let report = run_specs_supervised(&specs, 42, &opts(1)).unwrap();
    assert_eq!(report.scenarios.len(), specs.len());
    assert_eq!(report.scenarios[1].status, ScenarioStatus::Panicked);
    assert!(report.scenarios[1].retried, "a panic gets exactly one retry");
    let ok = report.scenarios.iter().filter(|s| s.status.is_ok()).count();
    assert_eq!(ok, specs.len() - 1, "every sibling must complete");
    let j1 = report.to_json();
    assert!(j1.contains("\"status\": \"panicked\""), "{j1}");
    assert!(j1.contains("\"failures\": {"), "{j1}");
    assert!(j1.contains("\"panicked\": 1"), "{j1}");
    // Byte-identity holds with a quarantined row in the sweep — across
    // worker counts and across repeats.
    let j4 = run_specs_supervised(&specs, 42, &opts(4)).unwrap().to_json();
    assert_eq!(j1, j4, "jobs must not change the report");
    let again = run_specs_supervised(&specs, 42, &opts(4)).unwrap().to_json();
    assert_eq!(j1, again, "same seed must reproduce exactly");
}

#[test]
fn budget_exhausted_scenario_reports_deterministically() {
    let mut specs = chat_slice(42);
    specs[0].budget_events = Some(50);
    let opts = SweepOptions::default();
    let a = run_specs_supervised(&specs, 42, &opts).unwrap();
    assert_eq!(a.scenarios[0].status, ScenarioStatus::BudgetExhausted);
    assert!(
        !a.scenarios[0].retried,
        "deterministic exhaustion is never retried"
    );
    assert!(a.scenarios[0]
        .error
        .as_deref()
        .unwrap()
        .contains("budget exhausted"));
    for s in &a.scenarios[1..] {
        assert!(s.status.is_ok(), "siblings must complete: {}", s.name);
    }
    // Budgets are pure functions of the config: the exhaustion point (and
    // therefore the whole report) is digest-stable across runs.
    let b = run_specs_supervised(&specs, 42, &opts).unwrap();
    assert_eq!(a.to_json(), b.to_json());
    assert!(a.to_json().contains("\"budget_exhausted\": 1"));
}

#[test]
fn resume_after_truncation_reproduces_the_uninterrupted_report() {
    let specs = chat_slice(42);
    let path = tmp("resume");
    let _ = std::fs::remove_file(&path);
    let straight = run_specs_supervised(
        &specs,
        42,
        &SweepOptions {
            jobs: 2,
            journal: Some(path.clone()),
            ..SweepOptions::default()
        },
    )
    .unwrap()
    .to_json();
    // Simulate a kill mid-sweep: keep the first half of the journal bytes,
    // cutting mid-line — the partial tail must be discarded, its scenario
    // (and everything after it) re-executed.
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.ends_with('\n'));
    std::fs::write(&path, &text.as_bytes()[..text.len() / 2]).unwrap();
    let resumed = run_specs_supervised(
        &specs,
        42,
        &SweepOptions {
            jobs: 4,
            journal: Some(path.clone()),
            resume: true,
            ..SweepOptions::default()
        },
    )
    .unwrap()
    .to_json();
    assert_eq!(
        straight, resumed,
        "killed-and-resumed must be byte-identical to uninterrupted"
    );
    // Resume again over the repaired journal (which now carries a partial
    // line mid-file): nothing re-executes, the report is reproduced.
    let replayed = run_specs_supervised(
        &specs,
        42,
        &SweepOptions {
            jobs: 1,
            journal: Some(path.clone()),
            resume: true,
            ..SweepOptions::default()
        },
    )
    .unwrap()
    .to_json();
    assert_eq!(straight, replayed);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn stale_journal_entries_for_a_changed_spec_are_ignored() {
    let specs = chat_slice(42);
    let path = tmp("stale");
    let _ = std::fs::remove_file(&path);
    run_specs_supervised(
        &specs,
        42,
        &SweepOptions {
            journal: Some(path.clone()),
            ..SweepOptions::default()
        },
    )
    .unwrap();
    // Change one spec without changing its name: its spec digest changes,
    // so the checkpointed entry is stale and must be re-executed — here the
    // changed spec trips its (tiny) event budget, which the stale `ok`
    // entry would have masked.
    let mut changed = specs.clone();
    changed[0].budget_events = Some(1);
    let resumed = run_specs_supervised(
        &changed,
        42,
        &SweepOptions {
            journal: Some(path.clone()),
            resume: true,
            ..SweepOptions::default()
        },
    )
    .unwrap();
    assert_eq!(
        resumed.scenarios[0].status,
        ScenarioStatus::BudgetExhausted,
        "a stale journal entry must not mask the changed spec"
    );
    for s in &resumed.scenarios[1..] {
        assert!(s.status.is_ok(), "unchanged specs replay from the journal");
    }
    let _ = std::fs::remove_file(&path);
}

/// The acceptance pin end-to-end through the CLI: journal a sweep, truncate
/// it mid-line, `--resume`, and compare report files byte-for-byte.
#[test]
fn cli_journal_resume_is_byte_identical() {
    let dir = std::env::temp_dir().join("cb_sweep_resilience_cli");
    std::fs::create_dir_all(&dir).unwrap();
    let journal = dir.join("sweep.jsonl");
    let _ = std::fs::remove_file(&journal);
    let straight_path = dir.join("straight.json");
    let resumed_path = dir.join("resumed.json");
    let run = |args: &[&str]| {
        let args: Vec<String> = args.iter().map(|s| s.to_string()).collect();
        let mut buf = Vec::new();
        run_cli(&args, &mut buf)
            .map(|_| String::from_utf8(buf).unwrap())
            .map_err(|e| format!("{e:#}"))
    };
    run(&[
        "scenario",
        "--filter",
        "mix=chat/",
        "--jobs",
        "2",
        "--journal",
        journal.to_str().unwrap(),
        "--out",
        straight_path.to_str().unwrap(),
    ])
    .unwrap();
    let text = std::fs::read_to_string(&journal).unwrap();
    std::fs::write(&journal, &text.as_bytes()[..text.len() / 3]).unwrap();
    run(&[
        "scenario",
        "--filter",
        "mix=chat/",
        "--jobs",
        "4",
        "--journal",
        journal.to_str().unwrap(),
        "--resume",
        "--out",
        resumed_path.to_str().unwrap(),
    ])
    .unwrap();
    assert_eq!(
        std::fs::read(&straight_path).unwrap(),
        std::fs::read(&resumed_path).unwrap(),
        "CLI resume must reproduce the report byte-for-byte"
    );
}
