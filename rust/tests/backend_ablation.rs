//! Golden tests for the kernel-backend axis (PR 5 tentpole acceptance).
//!
//! Pins the paper's §6 claim as executable assertions on the RTX 6000
//! profile:
//!
//! 1. **Decode throughput**: the tuned (llama.cpp-class) backend strictly
//!    beats generic PyTorch eager execution at every context length, and
//!    the idealized fused backend is at least as fast as tuned.
//! 2. **Chat SLO attainment under contention**: with a device-filling
//!    diffusion stream resident (the §4.2 greedy regime), tuned chat keeps
//!    full TPOT attainment while the generic backend's 4× launch count
//!    pushes every contended token past the 250 ms bound — tuned strictly
//!    beats generic.
//! 3. **Determinism**: the backend-ablation matrix slice renders
//!    byte-identical JSON across `--jobs 1` / `--jobs 4` and repeated runs,
//!    and diverges for a different seed.

use consumerbench::apps::models::llama_3_2_3b;
use consumerbench::apps::{slo_attainment, AppContext, Application, Chatbot, RequestMetrics};
use consumerbench::gpusim::backend::KernelBackend;
use consumerbench::gpusim::engine::{Engine, JobSpec, Phase};
use consumerbench::gpusim::kernel::{duration, Device, KernelDesc};
use consumerbench::gpusim::policy::Policy;
use consumerbench::gpusim::profiles::{rtx6000, Testbed};
use consumerbench::scenario::{run_specs_jobs, MatrixAxes, ScenarioSpec};

/// Exclusive-GPU seconds to decode one token at the given context.
fn decode_token_seconds(backend: KernelBackend, context: usize) -> f64 {
    let gpu = rtx6000();
    llama_3_2_3b()
        .with_backend(backend)
        .decode_kernels(context)
        .iter()
        .map(|k| duration(k, &gpu, gpu.num_sms).unwrap())
        .sum()
}

#[test]
fn tuned_strictly_beats_generic_decode_throughput() {
    for context in [512, 4096, 32_768] {
        let tuned = decode_token_seconds(KernelBackend::TunedNative, context);
        let generic = decode_token_seconds(KernelBackend::GenericTorch, context);
        assert!(
            tuned < generic,
            "ctx {context}: tuned {tuned} must beat generic {generic}"
        );
        // tokens/s, the §6 framing. The gap widens with context (the
        // generic backend's materialized attention intermediates scale
        // with the KV it reads).
        let speedup = generic / tuned;
        assert!(speedup > 1.05, "ctx {context}: speedup {speedup}");
    }
    let short = decode_token_seconds(KernelBackend::GenericTorch, 512)
        / decode_token_seconds(KernelBackend::TunedNative, 512);
    let long = decode_token_seconds(KernelBackend::GenericTorch, 32_768)
        / decode_token_seconds(KernelBackend::TunedNative, 32_768);
    assert!(long > short, "generic must degrade with context: {short} vs {long}");
    // The idealized hand-fused backend is at least as fast as llama.cpp.
    for context in [512, 4096] {
        assert!(
            decode_token_seconds(KernelBackend::FusedCustom, context)
                <= decode_token_seconds(KernelBackend::TunedNative, context),
            "ctx {context}: fused must not lose to tuned"
        );
    }
}

/// Drive a Chatbot closed-loop on an engine whose GPU is saturated by a
/// device-filling diffusion-style stream (168 regs/thread, grid spans the
/// device — the §4.2 greedy-contention regime), and evaluate every request
/// against the chat SLO.
fn contended_chat_metrics(backend: KernelBackend) -> Vec<RequestMetrics> {
    let mut e = Engine::new(Testbed::intel_server(), Policy::Greedy);
    let chat_client = e.register_client("chatbot");
    let hog_client = e.register_client("render");
    // ~100 s of back-to-back denoise-class kernels (~3.4 ms each at full
    // device): long enough to cover the whole tuned run.
    let hog = KernelDesc::new("denoise.attn", 2048, 256, 168, 16 * 1024, 3.5e10, 64e6);
    e.submit(
        JobSpec {
            client: hog_client,
            label: "render".into(),
            phases: vec![Phase::gpu("denoise", 0.0, vec![hog; 30_000])],
        },
        0.0,
    );
    let ctx = AppContext {
        client: chat_client,
        device: Device::Gpu,
    };
    let app = Chatbot::new(1, 3).with_backend(backend);
    e.submit(app.setup_job(&ctx), 0.0);
    let mut metrics = Vec::new();
    let mut next_submit = 2.0; // after the model load
    for i in 0..app.num_requests() {
        e.submit(app.request_job(&ctx, i), next_submit.max(e.now()));
        let label = format!("chatbot.req{}", app.requests()[i].id);
        'wait: loop {
            let t = e
                .next_event_time()
                .expect("request must complete before the event heap drains");
            e.run_until(t);
            for r in e.take_completed() {
                if r.label == label {
                    metrics.push(app.evaluate(&r));
                    break 'wait;
                }
            }
        }
        next_submit = e.now() + 0.1;
    }
    metrics
}

#[test]
fn tuned_strictly_beats_generic_chat_attainment_under_contention() {
    let tuned = contended_chat_metrics(KernelBackend::TunedNative);
    let generic = contended_chat_metrics(KernelBackend::GenericTorch);
    let att = |m: &[RequestMetrics]| slo_attainment(m).expect("requests ran");

    // llama.cpp-class kernels keep every contended token inside the 250 ms
    // TPOT bound (one ~3.4 ms queue wait per launch × 30 launches) …
    assert!(
        (att(&tuned) - 1.0).abs() < 1e-12,
        "tuned must keep full attainment: {:?}",
        tuned.iter().map(|m| m.normalized).collect::<Vec<_>>()
    );
    // … while the generic backend's 120 launches/token blow it: strictly
    // worse attainment, the §6 claim.
    assert!(
        att(&generic) < att(&tuned),
        "generic {} must lose to tuned {}",
        att(&generic),
        att(&tuned)
    );
    // The first request runs fully inside the contention window under both
    // backends (later requests may outlive it — the generic run takes 4×
    // longer): there the gap is a strict per-request fact, with the
    // generic TPOT past the SLO bound outright.
    assert!(
        generic[0].normalized > tuned[0].normalized,
        "generic normalized {} vs tuned {}",
        generic[0].normalized,
        tuned[0].normalized
    );
    assert!(!generic[0].slo_met, "contended generic chat must miss TPOT");
    assert!(tuned[0].slo_met);
}

/// The backend-ablation slice of the default matrix (6 scenarios:
/// 3 backends × {chat+imagegen, captions+imagegen}).
fn backend_slice(seed: u64) -> Vec<ScenarioSpec> {
    let mut specs = MatrixAxes::default_matrix(seed).expand();
    specs.retain(|s| s.name.starts_with("backend="));
    assert_eq!(specs.len(), 6);
    specs
}

#[test]
fn backend_slice_byte_identical_across_jobs_and_repeats() {
    let j1 = run_specs_jobs(&backend_slice(42), 42, 1).unwrap().to_json();
    let j4 = run_specs_jobs(&backend_slice(42), 42, 4).unwrap().to_json();
    assert_eq!(
        j1, j4,
        "backend-ablation JSON (incl. summary.backends) must be identical across jobs"
    );
    let again = run_specs_jobs(&backend_slice(42), 42, 4).unwrap().to_json();
    assert_eq!(j1, again, "same seed must reproduce exactly");
    // The backend column and summary rows are part of the pinned bytes.
    assert!(j1.contains("\"backend\": \"tuned_native\""), "{j1}");
    assert!(j1.contains("\"backend\": \"generic_torch\""));
    assert!(j1.contains("\"backend\": \"fused_custom\""));
    assert!(j1.contains("\"backends\": ["));
    assert!(j1.contains("\"mean_throughput_rps\""));
    // Seed divergence holds on the slice too.
    let other = run_specs_jobs(&backend_slice(43), 43, 4).unwrap().to_json();
    assert_ne!(j1, other);
}

#[test]
fn matrix_slice_reports_the_ablation_per_backend() {
    let report = run_specs_jobs(&backend_slice(42), 42, 4).unwrap();
    // One summary row per backend, each over both curated mixes.
    let rows = report.backend_rows();
    assert_eq!(rows.len(), 3);
    for r in &rows {
        assert_eq!(r.scenarios, 2, "{}", r.backend);
        assert!(r.mean_throughput_rps > 0.0, "{}", r.backend);
        assert!((0.0..=1.0).contains(&r.mean_min_attainment), "{}", r.backend);
    }
    let row = |key: &str| rows.iter().find(|r| r.backend == key).unwrap();
    // Same request counts everywhere, longer makespans under generic →
    // scenario-level throughput cannot favor the generic backend.
    assert!(
        row("tuned_native").mean_throughput_rps >= row("generic_torch").mean_throughput_rps,
        "tuned {} vs generic {}",
        row("tuned_native").mean_throughput_rps,
        row("generic_torch").mean_throughput_rps
    );
    // Scenario-level chat attainment under contention: tuned at least
    // matches generic in the same mix (the strict engine-level comparison
    // lives above, free of scheduler noise).
    let chat_att = |backend: &str| {
        report
            .scenarios
            .iter()
            .find(|s| s.backend == backend && s.mix == "chat+imagegen")
            .unwrap()
            .apps
            .iter()
            .find(|a| a.app == "Chatbot")
            .unwrap()
            .attainment
            .expect("chat requests ran")
    };
    assert!(chat_att("tuned_native") >= chat_att("generic_torch"));
}
