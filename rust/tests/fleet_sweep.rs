//! Fleet-sweep integration tests: the ISSUE-10 acceptance criteria.
//!
//! * the population report is **byte-identical** across `--jobs 1` vs
//!   `--jobs N` and across straight-through vs killed-and-resumed runs;
//! * peak resident aggregation state is bounded by
//!   `shards × (bins + outlier_k × trace_window)` — pinned at a
//!   2,000-device population;
//! * fleet p50/p99 agree with exact sorted percentiles within the
//!   documented histogram error bound;
//! * histogram/moment merges are associative, commutative, and
//!   shard-count-invariant (property tests over the in-repo kit).

use std::path::PathBuf;

use consumerbench::coordinator::config::AppType;
use consumerbench::coordinator::Strategy;
use consumerbench::gpusim::kernel::Device;
use consumerbench::prop_assert;
use consumerbench::scenario::{
    run_fleet, AppMix, FleetAggregate, FleetOptions, FleetSpec, MixEntry, PopulationSpec,
};
use consumerbench::util::json::{parse as json_parse, JsonValue};
use consumerbench::util::proptest::{check, Gen};
use consumerbench::util::stats::{FixedHistogram, Moments};

/// The cheapest mix the matrix vocabulary can express: one LiveCaptions
/// client serving a single request. Population-scale tests use it so the
/// 2,000-device sweep stays a smoke test, not a soak test.
fn captions_solo() -> AppMix {
    AppMix {
        name: "captions-solo",
        entries: vec![MixEntry {
            app: AppType::LiveCaptions,
            num_requests: 1,
            device: Device::Gpu,
        }],
    }
}

fn cheap_spec(count: usize, seed: u64, shard_size: usize) -> FleetSpec {
    let mut spec = FleetSpec::new(PopulationSpec::default_population(count, seed));
    spec.mix = captions_solo();
    spec.shard_size = shard_size;
    spec.trace_window = 64;
    spec
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("cb_fleet_{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn jobs_opts(jobs: usize) -> FleetOptions {
    FleetOptions {
        jobs,
        ..FleetOptions::default()
    }
}

// ---------------------------------------------------------------------------
// Byte-identity across --jobs
// ---------------------------------------------------------------------------

#[test]
fn fleet_report_byte_identical_across_jobs() {
    let spec = cheap_spec(30, 42, 5);
    let base = run_fleet(&spec, &jobs_opts(1)).unwrap().to_json();
    for jobs in [2, 4, 7] {
        let json = run_fleet(&spec, &jobs_opts(jobs)).unwrap().to_json();
        assert_eq!(base, json, "report drifted at jobs={jobs}");
    }
    // And across repeats at the same jobs count.
    let again = run_fleet(&spec, &jobs_opts(4)).unwrap().to_json();
    assert_eq!(base, again);
}

#[test]
fn fleet_report_carries_schema_and_population() {
    let spec = cheap_spec(12, 9, 4);
    let report = run_fleet(&spec, &jobs_opts(2)).unwrap();
    let json = report.to_json();
    assert!(json.starts_with("{\n  \"consumerbench_fleet\": 1,"), "{json}");
    let v = json_parse(&json).expect("report JSON parses");
    assert_eq!(
        v.get("devices").and_then(|d| d.get("total")).and_then(JsonValue::as_u64),
        Some(12)
    );
    assert_eq!(
        v.get("population").and_then(|p| p.get("seed")).and_then(JsonValue::as_u64),
        Some(9)
    );
    // Every sampled device landed in some tier row.
    let tiers = match v.get("tiers") {
        Some(JsonValue::Arr(rows)) => rows,
        other => panic!("tiers: {other:?}"),
    };
    let tier_devices: u64 = tiers
        .iter()
        .map(|t| t.get("devices").and_then(JsonValue::as_u64).unwrap())
        .sum();
    assert_eq!(tier_devices, 12);
}

// ---------------------------------------------------------------------------
// Kill / resume
// ---------------------------------------------------------------------------

#[test]
fn fleet_report_byte_identical_after_kill_and_resume() {
    let dir = tmp_dir("kill_resume");
    let spec = cheap_spec(18, 7, 4);

    // Straight-through run with a journal.
    let straight_journal = dir.join("straight.jsonl");
    let straight = run_fleet(
        &spec,
        &FleetOptions {
            jobs: 3,
            journal: Some(straight_journal.clone()),
            ..FleetOptions::default()
        },
    )
    .unwrap()
    .to_json();

    // Simulate a kill: keep a prefix of the journal and corrupt the tail
    // the way a mid-write kill would (a partial final line, no newline).
    let text = std::fs::read_to_string(&straight_journal).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 18, "every device journaled once");
    let killed_journal = dir.join("killed.jsonl");
    let mut partial = lines[..7].join("\n");
    partial.push('\n');
    partial.push_str(&lines[7][..lines[7].len() / 2]);
    std::fs::write(&killed_journal, &partial).unwrap();

    // Resume from the partial journal at a different jobs count.
    let resumed = run_fleet(
        &spec,
        &FleetOptions {
            jobs: 2,
            journal: Some(killed_journal.clone()),
            resume: true,
            ..FleetOptions::default()
        },
    )
    .unwrap()
    .to_json();
    assert_eq!(straight, resumed, "kill/resume must be byte-identical");

    // The repaired journal now covers every device; a second resume
    // re-executes nothing and leaves the journal untouched.
    let after_resume = std::fs::read_to_string(&killed_journal).unwrap();
    let full = run_fleet(
        &spec,
        &FleetOptions {
            jobs: 1,
            journal: Some(killed_journal.clone()),
            resume: true,
            ..FleetOptions::default()
        },
    )
    .unwrap()
    .to_json();
    assert_eq!(straight, full);
    assert_eq!(after_resume, std::fs::read_to_string(&killed_journal).unwrap());
}

#[test]
fn fleet_journal_with_stale_digest_is_ignored() {
    let dir = tmp_dir("stale_digest");
    let journal = dir.join("journal.jsonl");
    let spec = cheap_spec(8, 3, 4);
    run_fleet(
        &spec,
        &FleetOptions {
            jobs: 2,
            journal: Some(journal.clone()),
            ..FleetOptions::default()
        },
    )
    .unwrap();
    // A different population seed changes the spec digest: the journal is
    // stale, every device re-executes, and the result matches a fresh run.
    let mut reseeded = cheap_spec(8, 4, 4);
    reseeded.trace_window = spec.trace_window;
    let fresh = run_fleet(&reseeded, &jobs_opts(2)).unwrap().to_json();
    let resumed = run_fleet(
        &reseeded,
        &FleetOptions {
            jobs: 2,
            journal: Some(journal),
            resume: true,
            ..FleetOptions::default()
        },
    )
    .unwrap()
    .to_json();
    assert_eq!(fresh, resumed);
}

// ---------------------------------------------------------------------------
// Memory bound, pinned at a 2,000-device population
// ---------------------------------------------------------------------------

#[test]
fn fleet_memory_bound_pinned_at_2000_devices() {
    let spec = cheap_spec(2000, 7, 50);
    assert_eq!(spec.shards(), 40);
    let jobs = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let report = run_fleet(&spec, &jobs_opts(jobs)).unwrap();
    assert_eq!(report.agg.device_count(), 2000);

    // Peak resident aggregation state is bounded by the analytic
    // shards × (bins + outlier_k × trace_window) capacity — which has no
    // device-count term at all.
    let per_shard = FleetAggregate::shard_bound_cells(spec.outlier_k, spec.trace_window);
    assert_eq!(report.bound_cells, 40 * per_shard);
    assert!(
        report.resident_cells <= report.bound_cells,
        "resident {} > bound {}",
        report.resident_cells,
        report.bound_cells
    );
    // Pin the order of magnitude so the accounting itself cannot silently
    // inflate: 40 shards of (2 × ~100-bin histograms + 4 moment blocks +
    // ≤10 tiers + 8 outlier slots × 64-row windows) stays well under 100k
    // cells — nothing like the ~2000-device × O(trace) footprint the
    // materialize-everything approach would need.
    assert!(
        report.bound_cells < 100_000,
        "bound grew to {}",
        report.bound_cells
    );
    // The outlier table is the only place traces survive, and it is
    // bounded by k.
    assert!(report.agg.outliers().len() <= spec.outlier_k);
}

#[test]
fn fleet_resident_cells_do_not_scale_with_devices_per_shard() {
    // Same shard count, 8× the devices: the aggregation state may differ
    // only through tier-table occupancy, never through per-device growth.
    let small = run_fleet(&cheap_spec(40, 5, 10), &jobs_opts(2)).unwrap();
    let large = run_fleet(&cheap_spec(320, 5, 80), &jobs_opts(2)).unwrap();
    assert_eq!(small.shards, large.shards);
    let bound = large.bound_cells;
    assert!(small.resident_cells <= bound && large.resident_cells <= bound);
    // 8× devices must not even double the resident state (tier rows are
    // the only admissible growth).
    assert!(
        large.resident_cells < small.resident_cells * 2,
        "resident state scaled with devices: {} vs {}",
        large.resident_cells,
        small.resident_cells
    );
}

// ---------------------------------------------------------------------------
// Quantile accuracy vs exact sorted percentiles
// ---------------------------------------------------------------------------

#[test]
fn fleet_quantiles_match_exact_sorted_percentiles_within_bound() {
    let dir = tmp_dir("quantiles");
    let journal = dir.join("journal.jsonl");
    // The chat mix gives a real latency spread (server batching, queueing).
    let mut spec = FleetSpec::new(PopulationSpec::default_population(36, 13));
    spec.shard_size = 6;
    let report = run_fleet(
        &spec,
        &FleetOptions {
            jobs: 3,
            journal: Some(journal.clone()),
            ..FleetOptions::default()
        },
    )
    .unwrap();

    // Ground truth: every per-request latency, straight from the journal
    // the sweep itself wrote (ok rows only — exactly what was folded).
    let mut exact: Vec<f64> = Vec::new();
    for line in std::fs::read_to_string(&journal).unwrap().lines() {
        let v = json_parse(line).unwrap();
        if v.get("status").and_then(JsonValue::as_str) != Some("ok") {
            continue;
        }
        if let Some(JsonValue::Arr(lats)) = v.get("record").and_then(|r| r.get("latencies_s")) {
            exact.extend(lats.iter().map(|l| l.as_f64().unwrap()));
        }
    }
    assert!(!exact.is_empty(), "no ok devices in the quantile fixture");
    assert_eq!(exact.len() as u64, report.agg.latency_count());
    exact.sort_by(f64::total_cmp);

    // The histogram's documented contract is nearest-rank within half a
    // (geometric) bin: compare against the same nearest-rank convention.
    let rel_bound = FixedHistogram::log_scale(1e-4, 1e4, 96).error_bound();
    for q in [0.50, 0.90, 0.99] {
        let k = ((q * (exact.len() - 1) as f64).round() as usize).min(exact.len() - 1);
        let truth = exact[k];
        let approx = report.agg.latency_quantile(q).unwrap();
        assert!(
            (approx - truth).abs() <= truth * rel_bound + 1e-12,
            "q={q}: hist {approx} vs exact {truth} (rel bound {rel_bound})"
        );
    }
}

// ---------------------------------------------------------------------------
// Shard-size invariance of the exact aggregate fields
// ---------------------------------------------------------------------------

#[test]
fn fleet_shard_size_changes_grouping_not_exact_results() {
    let a = run_fleet(&cheap_spec(20, 11, 4), &jobs_opts(2)).unwrap();
    let b = run_fleet(&cheap_spec(20, 11, 7), &jobs_opts(3)).unwrap();
    // Histograms and counts merge exactly (u64 bins): any partition of the
    // same devices folds to the same totals.
    assert_eq!(a.agg.device_count(), b.agg.device_count());
    assert_eq!(a.agg.latency_count(), b.agg.latency_count());
    for q in [0.1, 0.5, 0.9, 0.99] {
        assert_eq!(a.agg.latency_quantile(q), b.agg.latency_quantile(q));
        assert_eq!(a.agg.attainment_quantile(q), b.agg.attainment_quantile(q));
    }
    assert_eq!(
        a.agg.outliers().iter().map(|r| r.device).collect::<Vec<_>>(),
        b.agg.outliers().iter().map(|r| r.device).collect::<Vec<_>>(),
    );
}

#[test]
fn fleet_strategy_changes_the_digest_and_the_slice() {
    let mut a = cheap_spec(6, 2, 3);
    a.strategy = Strategy::Greedy;
    let mut b = cheap_spec(6, 2, 3);
    b.strategy = Strategy::SloAware;
    assert_ne!(a.digest_hex(), b.digest_hex());
    // Both still run end to end.
    let ra = run_fleet(&a, &jobs_opts(2)).unwrap();
    let rb = run_fleet(&b, &jobs_opts(2)).unwrap();
    assert_eq!(ra.agg.device_count(), 6);
    assert_eq!(rb.agg.device_count(), 6);
}

// ---------------------------------------------------------------------------
// Mergeability property tests (util::proptest)
// ---------------------------------------------------------------------------

fn random_layout(g: &mut Gen) -> FixedHistogram {
    if g.u64(0, 2) == 0 {
        FixedHistogram::linear(0.0, g.f64(0.5, 100.0), g.usize(4, 64))
    } else {
        let lo = g.f64(1e-5, 1e-2);
        FixedHistogram::log_scale(lo, lo * g.f64(10.0, 1e6), g.usize(4, 128))
    }
}

#[test]
fn prop_histogram_merge_associative_commutative_partition_invariant() {
    check("hist_merge", 0xF1EE7, 200, |g| {
        let layout = random_layout(g);
        let samples = g.vec(120, |g| g.f64(-1.0, 150.0));

        // Whole fold.
        let mut whole = layout.clone();
        for &x in &samples {
            whole.fold(x);
        }

        // Random partition into three shards, merged in two different
        // association orders and one reversed (commuted) order.
        let cut1 = g.usize(0, samples.len() + 1);
        let cut2 = g.usize(cut1, samples.len() + 1);
        let mut parts: Vec<FixedHistogram> = Vec::new();
        for chunk in [&samples[..cut1], &samples[cut1..cut2], &samples[cut2..]] {
            let mut h = layout.clone();
            for &x in chunk {
                h.fold(x);
            }
            parts.push(h);
        }
        // (a ⊕ b) ⊕ c
        let mut left = parts[0].clone();
        left.merge(&parts[1]);
        left.merge(&parts[2]);
        // a ⊕ (b ⊕ c)
        let mut right_tail = parts[1].clone();
        right_tail.merge(&parts[2]);
        let mut right = parts[0].clone();
        right.merge(&right_tail);
        // c ⊕ b ⊕ a
        let mut rev = parts[2].clone();
        rev.merge(&parts[1]);
        rev.merge(&parts[0]);

        prop_assert!(left == whole, "left-assoc != whole fold");
        prop_assert!(right == whole, "right-assoc != whole fold");
        prop_assert!(rev == whole, "commuted merge != whole fold");
        Ok(())
    });
}

#[test]
fn prop_histogram_merge_is_shard_count_invariant() {
    check("hist_shards", 0x5AADD, 100, |g| {
        let layout = random_layout(g);
        let samples = g.vec(200, |g| g.f64(0.0, 120.0));
        let mut whole = layout.clone();
        for &x in &samples {
            whole.fold(x);
        }
        for shards in [1usize, 2, 3, 7, 16] {
            let size = samples.len().div_ceil(shards).max(1);
            let mut merged = layout.clone();
            for chunk in samples.chunks(size) {
                let mut h = layout.clone();
                for &x in chunk {
                    h.fold(x);
                }
                merged.merge(&h);
            }
            prop_assert!(merged == whole, "drift at {shards} shards");
        }
        Ok(())
    });
}

#[test]
fn prop_histogram_quantile_within_documented_error_bound() {
    check("hist_quantile", 0xB0BB1E5, 150, |g| {
        // Samples strictly inside the layout range so the bin bound (not
        // the boundary clamp) is what is being tested.
        let linear = g.u64(0, 2) == 0;
        let (layout, lo, hi) = if linear {
            let hi = g.f64(1.0, 50.0);
            (FixedHistogram::linear(0.0, hi, g.usize(32, 256)), 0.0, hi)
        } else {
            (FixedHistogram::log_scale(1e-4, 1e4, g.usize(48, 192)), 1e-4, 1e4)
        };
        let samples = {
            let mut v = g.vec(150, |g| g.f64(lo + (hi - lo) * 1e-9, hi * 0.999));
            if v.is_empty() {
                v.push((lo + hi) / 2.0);
            }
            v
        };
        let mut h = layout.clone();
        for &x in &samples {
            h.fold(x);
        }
        let mut sorted = samples.clone();
        sorted.sort_by(f64::total_cmp);
        let q = g.f64(0.0, 1.0);
        let k = ((q * (sorted.len() - 1) as f64).round() as usize).min(sorted.len() - 1);
        let truth = sorted[k];
        let approx = h.quantile(q).unwrap();
        let tolerance = if linear {
            h.error_bound() + 1e-12
        } else {
            truth * h.error_bound() + 1e-12
        };
        prop_assert!(
            (approx - truth).abs() <= tolerance,
            "q={q}: {approx} vs {truth} (tol {tolerance})"
        );
        Ok(())
    });
}

#[test]
fn prop_moments_merge_matches_sequential_fold() {
    check("moments_merge", 0xCAFE5, 200, |g| {
        let samples = g.vec(100, |g| g.f64(-50.0, 50.0));
        let mut whole = Moments::new();
        for &x in &samples {
            whole.push(x);
        }
        let cut = g.usize(0, samples.len() + 1);
        let (mut a, mut b) = (Moments::new(), Moments::new());
        for &x in &samples[..cut] {
            a.push(x);
        }
        for &x in &samples[cut..] {
            b.push(x);
        }
        a.merge(&b);
        prop_assert!(a.count() == whole.count(), "count drift");
        if whole.count() > 0 {
            prop_assert!(a.min() == whole.min() && a.max() == whole.max(), "extrema drift");
            let scale = whole.mean().abs().max(1.0);
            prop_assert!(
                (a.mean() - whole.mean()).abs() <= 1e-9 * scale,
                "mean drift: {} vs {}",
                a.mean(),
                whole.mean()
            );
            prop_assert!(
                (a.variance() - whole.variance()).abs() <= 1e-6 * whole.variance().max(1.0),
                "variance drift: {} vs {}",
                a.variance(),
                whole.variance()
            );
        }
        Ok(())
    });
}
