//! Paper-claim regression tests: each test pins one headline result from the
//! evaluation so calibration drift is caught by `cargo test`.
//!
//! Bands are deliberately loose — the assertions encode the paper's *shape*
//! (who wins, roughly by what factor), not testbed-absolute numbers.
//!
//! Triage note (scenario-matrix PR): the seed shipped with this suite
//! red — not because any band was miscalibrated, but because the crate had
//! no `Cargo.toml` and `runtime/` depended unconditionally on the
//! unpublished `xla` bindings, so `cargo test` could not compile at all.
//! The fix was adding the manifest and gating PJRT behind the `pjrt`
//! feature (default build uses `runtime::sim`); the behavioural assertions
//! below are unchanged — they run entirely on the virtual-time simulator,
//! which the `pjrt` feature does not influence.

use consumerbench::coordinator::run_config_text;

fn exclusive(app: &str, device: &str, n: usize, slo: &str) -> String {
    format!("App ({app}):\n  num_requests: {n}\n  device: {device}\n{slo}seed: 42\n")
}

/// §4.1 / Fig. 3: on the GPU every app meets its SLO.
#[test]
fn fig3_gpu_upper_bound() {
    for (app, n, slo) in [
        ("chatbot", 6, "  slo: [1s, 0.25s]\n"),
        ("imagegen", 3, "  slo: 1s\n"),
        ("livecaptions", 30, "  slo: 2s\n"),
    ] {
        let r = run_config_text(&exclusive(app, "gpu", n, slo), None).unwrap();
        let node = &r.nodes[0];
        let att = node.attainment().expect("requests ran");
        assert!(att >= 0.9, "{app} gpu attainment {att}");
    }
}

/// §4.1 / Fig. 3: LiveCaptions' only exclusive-GPU violations are the
/// ~2% language-ID re-encodes (3-in-150 in the paper).
#[test]
fn fig3_livecaptions_reencode_violations() {
    let r = run_config_text(&exclusive("livecaptions", "gpu", 150, "  slo: 2s\n"), None).unwrap();
    let node = &r.nodes[0];
    let misses = node.metrics.iter().filter(|m| !m.slo_met).count();
    assert!(
        (1..=8).contains(&misses),
        "expected a handful of re-encode misses out of 150, got {misses}"
    );
}

/// §4.1 / Fig. 3: CPU lower bound — Chatbot narrowly misses; ImageGen and
/// LiveCaptions blow out by an order of magnitude or more.
#[test]
fn fig3_cpu_lower_bound() {
    let chat = run_config_text(&exclusive("chatbot", "cpu", 6, "  slo: [1s, 0.25s]\n"), None)
        .unwrap();
    let n = chat.nodes[0].mean_normalized();
    assert!(n > 0.8 && n < 5.0, "chatbot cpu normalized {n} (narrow miss expected)");

    let img = run_config_text(&exclusive("imagegen", "cpu", 2, "  slo: 1s\n"), None).unwrap();
    assert!(img.nodes[0].mean_normalized() > 10.0);

    let cc = run_config_text(&exclusive("livecaptions", "cpu", 8, "  slo: 2s\n"), None).unwrap();
    assert!(cc.nodes[0].mean_normalized() > 1.5);
}

/// §4.1 / Fig. 4: occupancy ordering — Chatbot > ImageGen > Whisper-decode.
#[test]
fn fig4_occupancy_ordering() {
    use consumerbench::apps::models::*;
    use consumerbench::gpusim::kernel::occupancy;
    use consumerbench::gpusim::profiles::rtx6000;
    let gpu = rtx6000();
    let chat = occupancy(&llama_3_2_3b().decode_kernels(512)[0], &gpu).unwrap().occupancy;
    let sd = sd35_medium_turbo()
        .denoise_step_kernels()
        .into_iter()
        .find(|k| k.tag == "denoise.attn")
        .map(|k| occupancy(&k, &gpu).unwrap().occupancy)
        .unwrap();
    let whisper = occupancy(&whisper_large_v3_turbo().decode_token_kernels()[0], &gpu)
        .unwrap()
        .occupancy;
    assert!(chat > 0.6, "chat {chat}");
    assert!(sd < 0.35 && sd > 0.1, "sd {sd}");
    assert!(whisper < 0.1, "whisper {whisper}");
    assert!(chat > sd && sd > whisper);
}

fn fig5_config(strategy: &str) -> String {
    format!(
        "\
Chat (chatbot):
  num_requests: 6
  device: gpu
  slo: [1s, 0.25s]
Image (imagegen):
  num_requests: 12
  device: gpu
  slo: 1s
Captions (livecaptions):
  num_requests: 30
  device: gpu
  slo: 2s
strategy: {strategy}
seed: 42
"
    )
}

/// §4.2 / Fig. 5: greedy starves LiveCaptions (multi-x e2e inflation) while
/// ImageGen stays at its exclusive performance.
#[test]
fn fig5_greedy_starves_livecaptions() {
    let excl = run_config_text(
        "Captions (livecaptions):\n  num_requests: 30\n  device: gpu\n  slo: 2s\nseed: 42\n",
        None,
    )
    .unwrap();
    let excl_lat: f64 = excl.nodes[0].metrics.iter().map(|m| m.latency).sum::<f64>()
        / excl.nodes[0].metrics.len() as f64;

    let greedy = run_config_text(&fig5_config("greedy"), None).unwrap();
    let lc = greedy.node("Captions (livecaptions)").unwrap();
    let lat: f64 = lc.metrics.iter().map(|m| m.latency).sum::<f64>() / lc.metrics.len() as f64;
    assert!(
        lat / excl_lat > 4.0,
        "LiveCaptions e2e inflation {} (paper: ~12x)",
        lat / excl_lat
    );
    // ImageGen unaffected by contention under greedy.
    let ig = greedy.node("Image (imagegen)").unwrap();
    assert!(ig.mean_normalized() < 0.7, "imagegen normalized {}", ig.mean_normalized());
    assert!(ig.attainment().unwrap() > 0.95);
}

/// §4.2 / Fig. 5: partitioning protects LiveCaptions and pushes ImageGen to
/// (or past) its step budget.
#[test]
fn fig5_partition_tradeoff() {
    let part = run_config_text(&fig5_config("partition"), None).unwrap();
    let lc = part.node("Captions (livecaptions)").unwrap();
    let lc_att = lc.attainment().expect("requests ran");
    assert!(lc_att > 0.9, "LC attainment {lc_att}");
    let ig = part.node("Image (imagegen)").unwrap();
    assert!(
        ig.mean_normalized() > 0.9 && ig.mean_normalized() < 2.0,
        "imagegen should narrowly miss: {}",
        ig.mean_normalized()
    );
    let chat = part.node("Chat (chatbot)").unwrap();
    assert!(chat.attainment().unwrap() > 0.9);
}

fn fig6_config(kv: &str, ctx: usize) -> String {
    format!(
        "\
Chat (chatbot):
  num_requests: 25
  device: gpu
  server: llama
  slo: [1s, 0.25s]
Research (deepresearch):
  num_requests: 2
  device: gpu
  server: llama
servers:
  llama:
    model: Llama-3.2-3B
    context_window: {ctx}
    kv_placement: {kv}
strategy: greedy
seed: 42
"
    )
}

/// §4.2.1 / Fig. 6: KV-on-GPU serves chat fine; KV-on-CPU misses a large
/// fraction of chat SLOs.
#[test]
fn fig6_kv_placement_tradeoff() {
    let gpu_kv = run_config_text(&fig6_config("gpu", 4096), None).unwrap();
    let chat_gpu = gpu_kv.node("Chat (chatbot)").unwrap().attainment().expect("requests ran");
    let cpu_kv = run_config_text(&fig6_config("cpu", 131_072), None).unwrap();
    let chat_cpu = cpu_kv.node("Chat (chatbot)").unwrap().attainment().expect("requests ran");
    assert!(chat_gpu > 0.85, "gpu-kv attainment {chat_gpu}");
    assert!(
        chat_cpu < chat_gpu - 0.15,
        "cpu-kv must miss substantially more: {chat_cpu} vs {chat_gpu}"
    );
    assert!(chat_cpu < 0.85, "paper: ~40% misses; got attainment {chat_cpu}");
}

fn fig7_config(strategy: &str) -> String {
    format!(
        "\
Brainstorm (chatbot):
  num_requests: 6
  server: shared
  slo: [1s, 0.25s]
Analysis (deepresearch):
  num_requests: 1
  server: shared
Outline (chatbot):
  num_requests: 6
  slo: [1s, 0.25s]
Art (imagegen):
  num_requests: 4
  slo: 1s
Captions (livecaptions):
  num_requests: 20
  slo: 2s
servers:
  shared:
    model: Llama-3.2-3B
    context_window: 131072
    kv_placement: cpu
workflows:
  analysis:
    uses: Analysis (deepresearch)
    background: true
  brainstorm:
    uses: Brainstorm (chatbot)
  outline:
    uses: Outline (chatbot)
    depend_on: [\"brainstorm\", \"analysis\"]
  art:
    uses: Art (imagegen)
    depend_on: [\"outline\"]
  captions:
    uses: Captions (livecaptions)
    depend_on: [\"outline\"]
strategy: {strategy}
seed: 42
"
    )
}

/// §4.3 / Fig. 7: greedy finishes the content-creation workflow markedly
/// sooner than partitioning (paper: ~45%).
#[test]
fn fig7_greedy_workflow_faster() {
    let greedy = run_config_text(&fig7_config("greedy"), None).unwrap();
    let part = run_config_text(&fig7_config("partition"), None).unwrap();
    let saving = 1.0 - greedy.makespan / part.makespan;
    assert!(
        saving > 0.15,
        "greedy should be much faster: saving {:.2} ({} vs {})",
        saving,
        greedy.makespan,
        part.makespan
    );
}

/// §B.4 / Fig. 11: with Chatbot-8B on the CPU, two-way GPU contention still
/// degrades LiveCaptions under greedy, and partitioning fixes it.
#[test]
fn fig11_larger_model_two_way_contention() {
    let cfg = |strategy: &str| {
        format!(
            "\
Chat8B (chatbot):
  model: Llama-3.1-8B
  num_requests: 3
  device: cpu
  slo: [1s, 0.25s]
Image (imagegen):
  num_requests: 8
  device: gpu
  slo: 1s
Captions (livecaptions):
  num_requests: 20
  device: gpu
  slo: 2s
strategy: {strategy}
seed: 42
"
        )
    };
    let greedy = run_config_text(&cfg("greedy"), None).unwrap();
    let chat = greedy.node("Chat8B (chatbot)").unwrap();
    assert!(chat.attainment().unwrap() < 0.9, "8B-on-CPU should violate SLOs");
    let part = run_config_text(&cfg("partition"), None).unwrap();
    let lc_g = greedy.node("Captions (livecaptions)").unwrap().mean_normalized();
    let lc_p = part.node("Captions (livecaptions)").unwrap().mean_normalized();
    assert!(lc_p < lc_g, "partition should protect LC: {lc_p} vs {lc_g}");
}

/// §4.4 / Fig. 18: Apple Silicon's fair-share scheduler still degrades
/// LiveCaptions under concurrency, but less than Intel-greedy.
#[test]
fn fig18_apple_fairness() {
    let apple = |extra: &str| {
        format!(
            "\
Image (imagegen):
  num_requests: 6
  slo: 1s
Captions (livecaptions):
  num_requests: 15
  slo: 4s
testbed: macbook_m1_pro
strategy: fair_share
{extra}seed: 42
"
        )
    };
    let conc = run_config_text(&apple(""), None).unwrap();
    let lc = conc.node("Captions (livecaptions)").unwrap();
    // Degraded but not the catastrophic Intel-greedy starvation.
    assert!(lc.mean_normalized() < 6.0, "LC on M1 {}", lc.mean_normalized());
}

/// §5.2 extension ablation: SLO-aware scheduling protects LiveCaptions like
/// partitioning while keeping ImageGen at its greedy-level throughput and a
/// greedy-level makespan — the dynamic middle ground the paper calls for.
#[test]
fn sec52_slo_aware_dominates() {
    let greedy = run_config_text(&fig5_config("greedy"), None).unwrap();
    let part = run_config_text(&fig5_config("partition"), None).unwrap();
    let aware = run_config_text(&fig5_config("slo_aware"), None).unwrap();

    let lc = |r: &consumerbench::coordinator::ScenarioResult| {
        r.node("Captions (livecaptions)").unwrap().attainment().unwrap()
    };
    let ig = |r: &consumerbench::coordinator::ScenarioResult| {
        r.node("Image (imagegen)").unwrap().mean_normalized()
    };
    // Protects LiveCaptions at least as well as partitioning …
    assert!(lc(&aware) >= lc(&part) - 0.05, "{} vs {}", lc(&aware), lc(&part));
    assert!(lc(&aware) > lc(&greedy));
    // … without partitioning's ImageGen penalty …
    assert!(ig(&aware) < ig(&part) * 0.7, "{} vs {}", ig(&aware), ig(&part));
    // … or its makespan blowup.
    assert!(aware.makespan < part.makespan * 0.7);
    assert!(aware.makespan < greedy.makespan * 1.3);
}
