//! Golden-trace determinism suite.
//!
//! The simulator is a pure function of (config, seed): these tests pin that
//! property end-to-end so engine refactors that silently perturb scheduling
//! order, float summation order, or workload synthesis are caught by
//! `cargo test`. "Golden" here means *self-golden*: two runs of the same
//! scenario must be byte-identical (canonical trace encoding and JSON
//! report), and a different seed must diverge — no absolute numbers are
//! pinned, so legitimate calibration changes don't invalidate the suite.

use consumerbench::coordinator::run_config_text;
use consumerbench::gpusim::engine::{trace_canonical_bytes, trace_digest, Trace};
use consumerbench::scenario::{run_matrix, run_scenario, MatrixAxes};

/// A contended, open-loop heavy-traffic scenario: every arrival model and
/// two app classes in one config.
fn mixed_config(seed: u64) -> String {
    format!(
        "\
Chat (chatbot):
  num_requests: 4
  device: gpu
  arrival: poisson
  rate: 0.5
Captions (livecaptions):
  num_requests: 6
  device: gpu
Image (imagegen):
  num_requests: 2
  device: gpu
  arrival: trace
  trace: [0, 0.2, 6]
strategy: fair_share
seed: {seed}
"
    )
}

fn run_trace(seed: u64) -> Trace {
    let result = run_config_text(&mixed_config(seed), None).unwrap();
    result.trace
}

#[test]
fn same_seed_produces_byte_identical_trace() {
    let t1 = run_trace(42);
    let t2 = run_trace(42);
    assert!(!t1.is_empty());
    assert_eq!(
        trace_canonical_bytes(&t1),
        trace_canonical_bytes(&t2),
        "two runs of the same scenario+seed must be byte-identical"
    );
    assert_eq!(trace_digest(&t1), trace_digest(&t2));
}

#[test]
fn same_seed_produces_identical_metrics() {
    let collect = || {
        let result = run_config_text(&mixed_config(7), None).unwrap();
        let mut rows: Vec<(String, u64, u64)> = Vec::new();
        for node in &result.nodes {
            for m in &node.metrics {
                rows.push((
                    m.label.clone(),
                    m.latency.to_bits(),
                    m.normalized.to_bits(),
                ));
            }
        }
        (rows, result.makespan.to_bits())
    };
    assert_eq!(collect(), collect());
}

#[test]
fn different_seeds_diverge() {
    let d42 = trace_digest(&run_trace(42));
    let d43 = trace_digest(&run_trace(43));
    assert_ne!(d42, d43, "different seeds must produce different traces");
}

#[test]
fn matrix_report_is_byte_identical_across_runs() {
    // Small matrix (one mix, all three policies, Poisson heavy traffic) so
    // the byte-identity check stays fast; the default matrix is exercised
    // once below and through the CLI test.
    let axes = || {
        let mut a = MatrixAxes::default_matrix(42);
        a.mixes.truncate(1);
        a.workflows.clear();
        a.backends.clear();
        a.chaos.clear();
        a
    };
    let j1 = run_matrix(&axes()).unwrap().to_json();
    let j2 = run_matrix(&axes()).unwrap().to_json();
    assert_eq!(j1, j2, "matrix JSON report must reproduce exactly");
    let j3 = run_matrix(&MatrixAxes {
        seed: 43,
        ..axes()
    })
    .unwrap()
    .to_json();
    assert_ne!(j1, j3, "a different matrix seed must change the report");
}

#[test]
fn default_matrix_executes_with_full_coverage() {
    let axes = MatrixAxes::default_matrix(42);
    let report = run_matrix(&axes).unwrap();
    assert!(
        report.scenarios.len() >= 20,
        "acceptance floor: >= 20 scenarios, got {}",
        report.scenarios.len()
    );
    assert_eq!(
        report.strategies(),
        vec!["greedy", "partition", "fair_share", "slo_aware"],
        "three flat policies plus the workflow slice's slo_aware"
    );
    // The workflow axis is part of the default matrix: rows carry e2e
    // latency, an e2e SLO verdict, and a critical-path attribution.
    let wf_rows: Vec<_> = report
        .scenarios
        .iter()
        .filter(|s| s.workflow != "flat")
        .collect();
    assert_eq!(wf_rows.len(), 10, "curated workflow slice");
    for s in &wf_rows {
        assert!(s.e2e_latency > 0.0, "{}", s.name);
        assert!(s.e2e_slo_met.is_some(), "{}: workflow_slo verdict", s.name);
        assert!(s.critical_path.contains(" -> "), "{}: {}", s.name, s.critical_path);
        assert!(s.e2e_latency <= s.makespan + 1e-9, "{}", s.name);
    }
    assert!(!report.workflow_rows().is_empty());
    let mixes: std::collections::BTreeSet<&str> = report
        .scenarios
        .iter()
        .map(|s| s.mix.as_str())
        .collect();
    assert!(mixes.len() >= 3, "need >= 3 app mixes, got {mixes:?}");
    assert!(
        report.scenarios.iter().any(|s| s.arrival == "poisson"),
        "at least one open-loop Poisson workload"
    );
    // Every scenario actually executed its requests.
    for s in &report.scenarios {
        let total: usize = s.apps.iter().map(|a| a.requests).sum();
        assert!(total > 0, "{}: no requests ran", s.name);
        assert!(s.makespan > 0.0, "{}: empty makespan", s.name);
    }
    // Distinct scenarios produce distinct traces (policies/arrivals really
    // change engine behaviour rather than being cosmetic labels).
    let digests: std::collections::BTreeSet<u64> =
        report.scenarios.iter().map(|s| s.trace_digest).collect();
    assert!(
        digests.len() > report.scenarios.len() / 2,
        "suspiciously many identical traces: {} distinct of {}",
        digests.len(),
        report.scenarios.len()
    );
}

/// §4.3 / §5.2 golden workflow ablation: in the content-creation DAG the
/// critical path runs through the text branch (brainstorm → outline), which
/// greedy allocation starves behind the background b-roll render's
/// device-filling diffusion kernels — SLO-aware scheduling protects the
/// text stages and shortens the end-to-end latency.
#[test]
fn content_creation_greedy_starves_text_branch_slo_aware_shortens_e2e() {
    let spec = |policy: &str| {
        MatrixAxes::default_matrix(42)
            .expand()
            .into_iter()
            .find(|s| {
                s.name
                    == format!(
                        "workflow=content_creation/policy={policy}/testbed=intel_server/server=static"
                    )
            })
            .expect("content_creation spec in the default matrix")
    };
    let greedy = run_scenario(&spec("greedy")).unwrap();
    let aware = run_scenario(&spec("slo_aware")).unwrap();

    // The critical path runs through the text branch under both policies
    // (brainstorm gates the outline, which gates both leaves) …
    for r in [&greedy, &aware] {
        assert!(
            r.critical_path.starts_with("brainstorm -> outline"),
            "{}: {}",
            r.name,
            r.critical_path
        );
    }
    // … and under greedy that branch is starved: the outline's chat
    // requests queue behind the b-roll diffusion kernels.
    let outline_p99 = |r: &consumerbench::scenario::ScenarioOutcome| {
        r.apps
            .iter()
            .find(|a| a.node == "outline")
            .unwrap()
            .p99_latency
            .expect("outline completed requests")
    };
    assert!(
        outline_p99(&greedy) > outline_p99(&aware),
        "greedy must starve the outline: {} vs {}",
        outline_p99(&greedy),
        outline_p99(&aware)
    );
    // SLO-aware scheduling shortens the workflow's end-to-end latency.
    assert!(
        aware.e2e_latency < greedy.e2e_latency,
        "slo_aware must shorten e2e: {} vs {}",
        aware.e2e_latency,
        greedy.e2e_latency
    );
}

/// ISSUE 6 golden chaos ablation: under an injected fault regime the static
/// server configuration loses tight-SLO attainment that the adaptive
/// controller wins back — for at least one of the disruptive fault classes
/// (thermal throttle's clock-capped kernels, server crash's dropped
/// batches), adaptive must strictly beat static on min attainment.
#[test]
fn chaos_ablation_adaptive_recovers_attainment_static_loses() {
    let spec = |kind: &str, mode: &str| {
        MatrixAxes::default_matrix(42)
            .expand()
            .into_iter()
            .find(|s| {
                s.name
                    == format!(
                        "chaos={kind}/mix=chat+imagegen/policy=slo_aware/testbed=intel_server/server={mode}"
                    )
            })
            .expect("chaos spec in the default matrix")
    };
    let mut best_delta = f64::NEG_INFINITY;
    for kind in ["thermal_throttle", "server_crash"] {
        let stat = run_scenario(&spec(kind, "static")).unwrap();
        let adap = run_scenario(&spec(kind, "adaptive")).unwrap();
        // Faulted scenarios still run to completion: every request is
        // served despite throttling windows or mid-batch crashes.
        for r in [&stat, &adap] {
            let total: usize = r.apps.iter().map(|a| a.requests).sum();
            assert!(total > 0, "{}: no requests ran", r.name);
            for a in &r.apps {
                assert!(a.failed.is_none(), "{}: {} failed: {:?}", r.name, a.node, a.failed);
            }
        }
        best_delta = best_delta.max(adap.min_attainment - stat.min_attainment);
    }
    assert!(
        best_delta > 0.0,
        "adaptive must strictly beat static under at least one fault class \
         (best attainment delta: {best_delta})"
    );
}

#[test]
fn open_loop_poisson_models_queueing_not_lockstep() {
    // Closed loop: a new chat request only starts after the previous one
    // finishes (+ think time). Open-loop Poisson at a high rate issues
    // arrivals independent of completions, so the same request count can
    // overlap and the span from first to last completion shrinks below the
    // closed-loop span with its 5 s think gaps.
    let closed = run_config_text(
        "Chat (chatbot):\n  num_requests: 4\n  device: gpu\nseed: 9\n",
        None,
    )
    .unwrap();
    let open = run_config_text(
        "Chat (chatbot):\n  num_requests: 4\n  device: gpu\n  arrival: poisson\n  rate: 20.0\nseed: 9\n",
        None,
    )
    .unwrap();
    assert_eq!(open.nodes[0].metrics.len(), 4);
    assert!(
        open.nodes[0].duration() < closed.nodes[0].duration(),
        "high-rate open loop should finish sooner than think-gated closed loop: {} vs {}",
        open.nodes[0].duration(),
        closed.nodes[0].duration()
    );
}
