//! Determinism-under-parallelism suite.
//!
//! The scenario sweep is executed by a work-stealing pool whose workers
//! finish in nondeterministic wall-clock order; these tests pin the
//! contract that makes that safe: the matrix report (and every per-scenario
//! trace digest inside it) is **byte-identical** across `--jobs 1`,
//! `--jobs 4`, and repeated runs with the same seed — and diverges for a
//! different seed. The workflow axis gets its own identity checks (its
//! critical-path and e2e columns are part of the report bytes); the
//! backend-ablation slice has its own suite in `tests/backend_ablation.rs`.
//! The last test pins the acceptance path end-to-end through the CLI on
//! the full 276-scenario sweep (96 static + 72 adaptive flat, 32 static +
//! 8 adaptive workflow, 48 backend-ablation, 20 chaos — reconfiguration
//! and fault events are part of the pinned digests).

use consumerbench::cli::run_cli;
use consumerbench::scenario::{run_matrix_jobs, run_specs_jobs, MatrixAxes};

/// A small but heterogeneous matrix: two mixes × three policies × two
/// arrival models × both server modes (24 scenarios, half of them
/// adaptive) keeps byte-identity checks fast while still covering the
/// controller path. The workflow slice has its own suite below.
fn small_axes(seed: u64) -> MatrixAxes {
    let mut axes = MatrixAxes::default_matrix(seed);
    axes.mixes.truncate(2);
    axes.workflows.clear();
    axes.backends.clear();
    axes.chaos.clear();
    axes
}

#[test]
fn jobs_do_not_change_the_report() {
    let sequential = run_matrix_jobs(&small_axes(42), 1).unwrap();
    let parallel = run_matrix_jobs(&small_axes(42), 4).unwrap();
    assert_eq!(
        sequential.to_json(),
        parallel.to_json(),
        "matrix JSON must be byte-identical across --jobs 1 and --jobs 4"
    );
    // The per-scenario golden fingerprints agree individually, too.
    let digests = |r: &consumerbench::scenario::MatrixReport| -> Vec<(String, u64)> {
        r.scenarios
            .iter()
            .map(|s| (s.name.clone(), s.trace_digest))
            .collect()
    };
    assert_eq!(digests(&sequential), digests(&parallel));
}

#[test]
fn repeated_parallel_runs_are_byte_identical() {
    let a = run_matrix_jobs(&small_axes(7), 4).unwrap().to_json();
    let b = run_matrix_jobs(&small_axes(7), 4).unwrap().to_json();
    assert_eq!(a, b, "same seed + same jobs must reproduce exactly");
}

#[test]
fn different_seeds_diverge_under_parallelism() {
    let a = run_matrix_jobs(&small_axes(42), 4).unwrap().to_json();
    let b = run_matrix_jobs(&small_axes(43), 4).unwrap().to_json();
    assert_ne!(a, b, "a different seed must change the parallel report");
}

/// The default matrix's workflow slice (10 scenarios: 4 DAG shapes ×
/// {greedy, slo_aware}, plus the content_creation adaptive pair).
fn workflow_specs(seed: u64) -> Vec<consumerbench::scenario::ScenarioSpec> {
    let mut specs = MatrixAxes::default_matrix(seed).expand();
    specs.retain(|s| s.name.starts_with("workflow="));
    assert_eq!(specs.len(), 10);
    specs
}

#[test]
fn workflow_scenarios_byte_identical_across_jobs_and_repeats() {
    let j1 = run_specs_jobs(&workflow_specs(42), 42, 1).unwrap().to_json();
    let j4 = run_specs_jobs(&workflow_specs(42), 42, 4).unwrap().to_json();
    assert_eq!(
        j1, j4,
        "workflow-axis JSON (incl. critical-path fields) must be identical across jobs"
    );
    let again = run_specs_jobs(&workflow_specs(42), 42, 4).unwrap().to_json();
    assert_eq!(j1, again, "same seed must reproduce exactly");
    // The critical-path/e2e columns are present and pinned by the identity.
    assert!(j1.contains("\"critical_path\": \""), "{j1}");
    assert!(j1.contains("\"e2e_latency_s\""));
    assert!(j1.contains("\"e2e_slo_met\""));
    assert!(j1.contains("\"workflows\": ["), "summary.workflows present");
    // Seed divergence holds on the workflow slice too.
    let other = run_specs_jobs(&workflow_specs(43), 43, 4).unwrap().to_json();
    assert_ne!(j1, other);
}

#[test]
fn oversubscribed_pool_clamps_to_matrix_size() {
    let mut axes = small_axes(3);
    axes.mixes.truncate(1);
    axes.strategies.truncate(1);
    axes.arrivals.truncate(1); // a single scenario
    let one = run_matrix_jobs(&axes, 1).unwrap().to_json();
    let many = run_matrix_jobs(&axes, 32).unwrap().to_json();
    assert_eq!(one, many);
}

/// The acceptance pin: `consumerbench scenario --full --seed S --jobs 1`
/// and `--jobs N` produce byte-identical JSON report files.
#[test]
fn cli_full_sweep_byte_identical_across_jobs() {
    let dir = std::env::temp_dir().join("cb_parallel_full");
    std::fs::create_dir_all(&dir).unwrap();
    let mut reports = Vec::new();
    for jobs in ["1", "4"] {
        let path = dir.join(format!("full_j{jobs}.json"));
        let args: Vec<String> = [
            "scenario",
            "--full",
            "--seed",
            "5",
            "--jobs",
            jobs,
            "--out",
            path.to_str().unwrap(),
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let mut buf = Vec::new();
        run_cli(&args, &mut buf).unwrap_or_else(|e| panic!("--jobs {jobs}: {e}"));
        reports.push(std::fs::read(&path).unwrap());
    }
    assert_eq!(
        reports[0], reports[1],
        "full-sweep JSON must be byte-identical for --jobs 1 and --jobs 4"
    );
    let text = String::from_utf8(reports[0].clone()).unwrap();
    // detlint: pin(full-matrix-count: 276)
    assert!(
        text.contains("\"num_scenarios\": 276"),
        "full sweep is 168 flat + 40 workflow + 48 backend-ablation + 20 chaos scenarios"
    );
    assert!(text.contains("\"testbed\": \"macbook_m1_pro\""));
    assert!(text.contains("\"server_mode\": \"adaptive\""));
    assert!(text.contains("\"workflow\": \"diamond\""));
    assert!(text.contains("workflow=content_creation/policy=partition"));
    assert!(text.contains("backend=generic_torch/mix=chat+imagegen/policy=slo_aware"));
    assert!(text.contains("\"backends\": ["));
    assert!(text.contains("chaos=vram_ballast/mix=chat+imagegen/policy=slo_aware/testbed=macbook_m1_pro"));
    assert!(text.contains("\"chaos\": ["));
}
